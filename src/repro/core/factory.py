"""Worker factory — the TaskVine-factory analogue — and the elastic
runner that drives a LIVE PCMManager from a capacity trace.

:class:`WorkerFactory` watches the opportunistic capacity signal (a trace
in simulation; a cluster API in production) and reconciles the worker pool
against it: spawn directives when capacity rises, and — because
opportunistic preemption is the CLUSTER's decision, not ours — the
preemption events the trace dictates. The factory is reactive (paper §1):
it never requests capacity, it adapts to what appears/disappears.

:class:`ElasticRunner` is the live half: it applies the factory's
directives to a running :class:`~repro.core.manager.PCMManager` on a real
clock (``add_worker``/``preempt_worker``, with the trace's heterogeneous
DeviceProfiles attached to the live workers), either stepped explicitly
(``step()``, deterministic — what the policy-parity tests use) or from a
background reconcile thread (``start()``/``stop()``). ``time_scale``
compresses trace time so an hours-long capacity trace can drive a
seconds-long live run: ``trace_t = wall_elapsed * time_scale``.
"""

from __future__ import annotations

import itertools
import threading
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Mapping, Optional, Set, Tuple


@dataclass
class PoolDirective:
    kind: str              # "join" | "leave"
    worker_id: str
    profile_name: str = ""
    t: float = 0.0


class WorkerFactory:
    """Reconciles the worker pool to a capacity function.

    ``capacity_fn(t) -> list[profile_name]`` describes which opportunistic
    slots exist at time t (one entry per available GPU/slice, identified by
    device profile). Heterogeneity is first-class: slots carry profiles.
    """

    def __init__(self, capacity_fn: Callable[[float], List[str]],
                 min_workers: int = 0, max_workers: int = 10_000,
                 name_prefix: str = "w"):
        self.capacity_fn = capacity_fn
        self.min_workers = min_workers
        self.max_workers = max_workers
        self._ids = itertools.count()
        self._prefix = name_prefix
        self.live: Dict[str, str] = {}       # worker_id -> profile name

    def reconcile(self, t: float) -> List[PoolDirective]:
        want = list(self.capacity_fn(t))[:self.max_workers]
        directives: List[PoolDirective] = []

        # count per profile
        want_counts: Dict[str, int] = {}
        for p in want:
            want_counts[p] = want_counts.get(p, 0) + 1
        have_counts: Dict[str, int] = {}
        for p in self.live.values():
            have_counts[p] = have_counts.get(p, 0) + 1

        # leaves: profiles with surplus (cluster reclaimed those slots)
        for profile, have in sorted(have_counts.items()):
            surplus = have - want_counts.get(profile, 0)
            if surplus > 0:
                victims = [wid for wid, p in sorted(self.live.items())
                           if p == profile][:surplus]
                for wid in victims:
                    del self.live[wid]
                    directives.append(PoolDirective("leave", wid, profile, t))

        # joins: profiles with deficit
        for profile, want_n in sorted(want_counts.items()):
            deficit = want_n - have_counts.get(profile, 0)
            for _ in range(max(0, deficit)):
                wid = f"{self._prefix}{next(self._ids):04d}"
                self.live[wid] = profile
                directives.append(PoolDirective("join", wid, profile, t))
        return directives

    @property
    def size(self) -> int:
        return len(self.live)


class ElasticRunner:
    """Drives a live PCMManager's worker pool from a capacity function.

    The live analogue of ``ClusterSimulator._reconcile``: every
    ``reconcile_every`` wall seconds (or every explicit ``step()``) the
    factory's directives are applied to the manager — ``join`` spawns a
    real worker actor carrying the slot's DeviceProfile, ``leave``
    preempts it with no warning (contexts demote to the node snapshot
    pool; joiners later restore peer-to-peer or from the pool).

    ``profiles`` maps trace profile names to DeviceProfile objects and
    defaults to ``repro.cluster.devices.PROFILES`` (imported lazily so the
    core package stays cluster-free at import time). ``time_scale``
    compresses trace time against the manager clock.

    With ``spawn_remote=True`` a ``join`` directive spawns a WHOLE WORKER
    PROCESS (``repro.cluster.node``) that connects to the manager's
    socket transport instead of an in-process actor thread — the manager
    must be ``listen()``-ing first. Joins become asynchronous (the worker
    appears when its HELLO lands), which is exactly how an opportunistic
    cluster behaves; ``leave`` retires the node through the same
    preemption path (its contexts demote over the wire into the manager
    pool) and the process exits on the BYE handshake. ``node_kwargs``
    passes through to :func:`repro.cluster.node.spawn_node_process`
    (AOT cache dir, extra import paths, heartbeat cadence).
    """

    def __init__(self, manager, capacity_fn: Callable[[float], List[str]],
                 profiles: Optional[Mapping[str, object]] = None,
                 reconcile_every: float = 0.25,
                 time_scale: float = 1.0,
                 max_workers: int = 10_000,
                 name_prefix: str = "w",
                 spawn_remote: bool = False,
                 node_kwargs: Optional[Dict] = None):
        if profiles is None:
            from repro.cluster.devices import PROFILES as profiles
        self.manager = manager
        self.profiles = profiles
        self.factory = WorkerFactory(capacity_fn, max_workers=max_workers,
                                     name_prefix=name_prefix)
        self.reconcile_every = reconcile_every
        self.time_scale = time_scale
        self.spawn_remote = spawn_remote
        self.node_kwargs = dict(node_kwargs or {})
        self.procs: Dict[str, object] = {}        # worker_id -> Popen
        self.events: List[PoolDirective] = []     # every applied directive
        self.joins = 0
        self.preemptions = 0
        self._stop = threading.Event()
        self._thread: Optional[threading.Thread] = None

    # ------------------------------------------------------------- drive ---
    def trace_now(self) -> float:
        """The trace clock: manager seconds compressed by ``time_scale``."""
        return self.manager.now * self.time_scale

    def step(self, trace_t: Optional[float] = None) -> List[PoolDirective]:
        """One reconcile pass at trace time ``trace_t`` (default: the
        scaled manager clock). Deterministic given the trace — tests and
        the policy-parity harness call this directly."""
        t = self.trace_now() if trace_t is None else trace_t
        applied: List[PoolDirective] = []
        for d in self.factory.reconcile(t):
            if d.kind == "join":
                if self.spawn_remote:
                    self._spawn_node(d)
                else:
                    self.manager.add_worker(
                        worker_id=d.worker_id,
                        profile=self.profiles.get(d.profile_name))
                self.joins += 1
            else:
                self._leave(d.worker_id)
                self.preemptions += 1
            applied.append(d)
        self.events.extend(applied)
        self._reap()
        return applied

    def _spawn_node(self, d: PoolDirective):
        from repro.cluster.node import spawn_node_process
        addr = self.manager.address
        if addr is None:
            raise RuntimeError(
                "spawn_remote=True requires manager.listen() before the "
                "first join directive")
        profile = d.profile_name \
            if d.profile_name in self.profiles else None
        self.procs[d.worker_id] = spawn_node_process(
            addr, d.worker_id, profile=profile, **self.node_kwargs)

    def _leave(self, worker_id: str):
        proc = self.procs.get(worker_id)
        if proc is not None and worker_id not in self.manager.workers:
            # reclaimed before its HELLO ever landed: nothing to retire —
            # kill the half-started process so it cannot join a pool that
            # no longer wants it
            self.procs.pop(worker_id, None)
            try:
                proc.terminate()
            except Exception:
                pass
            return
        # joined workers (thread or process) retire through the normal
        # preemption path; a node process exits on the BYE handshake and
        # is reaped on a later step
        self.manager.preempt_worker(worker_id)

    def _reap(self):
        """Collect node processes that exited after retiring."""
        for wid, proc in list(self.procs.items()):
            if getattr(proc, "poll", lambda: None)() is not None:
                self.procs.pop(wid, None)

    def run_for(self, wall_seconds: float):
        """Blocking drive loop for ``wall_seconds`` of wall time."""
        import time as _time
        deadline = _time.monotonic() + wall_seconds
        while _time.monotonic() < deadline and not self._stop.is_set():
            self.step()
            self._stop.wait(self.reconcile_every)

    def start(self) -> "ElasticRunner":
        """Reconcile from a background thread until ``stop()``."""
        if self._thread is not None:
            raise RuntimeError("ElasticRunner already started")
        self._stop.clear()

        def loop():
            while not self._stop.is_set():
                try:
                    self.step()
                except Exception:
                    import sys
                    import traceback
                    traceback.print_exc(file=sys.stderr)
                self._stop.wait(self.reconcile_every)

        self._thread = threading.Thread(target=loop, daemon=True,
                                        name="pcm-elastic-runner")
        self._thread.start()
        return self

    def stop(self):
        self._stop.set()
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    @property
    def size(self) -> int:
        return self.factory.size

    def stats(self) -> Dict:
        return {"pool_size": self.size, "joins": self.joins,
                "preemptions": self.preemptions,
                "node_procs": len(self.procs),
                "trace_now": self.trace_now()}
