"""Versioned wire format for context snapshots and stripe templates.

This is the serialization layer that lets a :class:`ContextSnapshot` (or a
streamed-transfer template) cross a PROCESS boundary: everything the
in-process peer path shares by pointer — the structural clone with its AOT
executables, the host pytrees, the chunk plan — is re-expressed as bytes
plus enough metadata for the receiver to rebuild an identical object.

Three rules shape the format:

1.  **Arrays travel through ``checkpoint/io``'s chunked-sha256 path.**
    ``pack_tree``/``unpack_tree`` give every leaf (and every 64 MB chunk of
    every large leaf) an individual digest, so a corrupt or truncated
    transfer is detected at chunk granularity (``ChunkCorruptionError``
    with ``where="wire"``) exactly like a corrupt spill file — one
    integrity story for disk and network.

2.  **Executables never cross the wire; recipes do.** Components exposing
    ``wire_recipe()`` (duck-typed — core never imports the serving layer)
    are replaced by a JSON *AOTRecipe*: the full constructor configuration
    plus an ``aot fingerprint`` (config hash + bucket set + megastep K +
    paged/prefix flags + jax/jaxlib versions). The receiver re-runs the
    named loader (``"module:function"``, resolved via importlib), which
    re-lowers and — when a shared AOT cache directory is configured —
    resolves every executable through a compile-cache HIT instead of a
    true XLA recompile. Shipping a recipe instead of a pickled executable
    keeps the format stable across jaxlib versions: a fingerprint mismatch
    degrades to a (counted) recompile, never to undefined behavior.

3.  **Structure is exact.** Pytree structure travels as a pickled treedef
    plus a leaf table; non-array leaves (page-axis ints, None markers) ride
    in a pickled sidecar keyed by leaf index, so the decoded tree is
    structurally identical to the encoded one — not merely array-equal.

Blob layout (little-endian)::

    b"PCMW" | u16 version | u32 header_len | JSON header | sections...

The JSON header carries the section offset table, the array manifest
(shapes/dtypes/per-chunk sha256), the component recipes and the scalar
meta; binary sections carry pickles (skeleton, recipe, treedefs, sidecar)
and the packed array payload.
"""

from __future__ import annotations

import importlib
import json
import pickle
import struct
from typing import Any, Dict, List, Optional, Tuple

import numpy as np

WIRE_MAGIC = b"PCMW"
WIRE_VERSION = 1


class WireError(RuntimeError):
    """Malformed, truncated or version-incompatible wire blob."""


class _WirePlaceholder:
    """Stands in for a recipe-encoded component inside the pickled value
    skeleton. Module-level (picklable); ``index`` points into the header's
    recipe table."""

    __slots__ = ("index",)

    def __init__(self, index: int):
        self.index = index

    def __reduce__(self):
        return (_WirePlaceholder, (self.index,))


# ------------------------------------------------------------- helpers -----
def _split_value(value: Any) -> Tuple[Any, List[Dict]]:
    """Walk the context value ONE level (the same ``_reachable`` shapes
    builders actually return) and pull out every component that knows how
    to describe itself as a wire recipe. Everything else stays in the
    skeleton and must be plain-picklable."""
    recipes: List[Dict] = []

    def enc(v):
        fn = getattr(v, "wire_recipe", None)
        if callable(fn):
            recipes.append(fn())
            return _WirePlaceholder(len(recipes) - 1)
        return v

    if isinstance(value, dict):
        skel: Any = {k: enc(v) for k, v in value.items()}
    elif isinstance(value, (list, tuple)):
        skel = type(value)(enc(v) for v in value)
    else:
        skel = enc(value)
    return skel, recipes


def load_component(rec: Dict) -> Any:
    """Rebuild one component from its wire recipe by importing and calling
    its named loader (``"pkg.mod:function"``). The loader owns all
    reconstruction semantics (for engines: a device-state-less shell whose
    executables resolve through the AOTRecipe cache)."""
    loader = rec.get("loader", "")
    if ":" not in loader:
        raise WireError(f"wire recipe has no importable loader: {rec!r}")
    mod_name, _, attr = loader.partition(":")
    try:
        fn = getattr(importlib.import_module(mod_name), attr)
    except Exception as exc:
        raise WireError(f"cannot import wire loader {loader!r}: {exc}")
    return fn(rec)


def _join_value(skel: Any, recipes: List[Dict]) -> Any:
    def dec(v):
        if isinstance(v, _WirePlaceholder):
            return load_component(recipes[v.index])
        return v

    if isinstance(skel, dict):
        return {k: dec(v) for k, v in skel.items()}
    if isinstance(skel, (list, tuple)):
        return type(skel)(dec(v) for v in skel)
    return dec(skel)


def _is_arrayish(leaf: Any) -> bool:
    return hasattr(leaf, "shape") and hasattr(leaf, "dtype") and \
        hasattr(leaf, "__array__")


def _pack_state(tree: Any, chunk_bytes: int) -> Tuple[Dict, bytes, bytes]:
    """Flatten an arbitrary host pytree into (json_table, pickled_sidecar,
    packed_payload). Array leaves go through ``pack_tree`` keyed by leaf
    index; non-array leaves (ints, None, small metadata) go into the
    pickled sidecar so their exact Python types survive the round trip."""
    import jax
    from repro.checkpoint.io import pack_tree

    leaves_with_path = jax.tree_util.tree_flatten_with_path(tree)[0]
    treedef = jax.tree_util.tree_structure(tree)
    arrays: Dict[str, np.ndarray] = {}
    sidecar: Dict[int, Any] = {}
    for idx, (_path, leaf) in enumerate(leaves_with_path):
        if _is_arrayish(leaf):
            arrays[f"L{idx:05d}"] = np.asarray(leaf)
        else:
            sidecar[idx] = leaf
    manifest, payload = pack_tree(arrays, chunk_bytes=chunk_bytes)
    table = {"n_leaves": len(leaves_with_path), "manifest": manifest}
    side = pickle.dumps({"treedef": treedef, "sidecar": sidecar},
                        protocol=pickle.HIGHEST_PROTOCOL)
    return table, side, payload


def _unpack_state(table: Dict, side: bytes, payload: bytes) -> Any:
    import jax
    from repro.checkpoint.io import unpack_tree

    meta = pickle.loads(side)
    flat = unpack_tree(table["manifest"], payload)
    sidecar = meta["sidecar"]
    leaves = []
    for idx in range(int(table["n_leaves"])):
        if idx in sidecar:
            leaves.append(sidecar[idx])
        else:
            leaves.append(flat[f"L{idx:05d}"])
    return jax.tree_util.tree_unflatten(meta["treedef"], leaves)


def _frame(kind: str, header_extra: Dict, sections: Dict[str, bytes]
           ) -> bytes:
    offsets = {}
    pos = 0
    order = list(sections.keys())
    for name in order:
        offsets[name] = [pos, len(sections[name])]
        pos += len(sections[name])
    header = dict(header_extra)
    header["kind"] = kind
    header["sections"] = offsets
    hdr = json.dumps(header, sort_keys=True).encode()
    return b"".join([WIRE_MAGIC, struct.pack("<HI", WIRE_VERSION, len(hdr)),
                     hdr] + [sections[n] for n in order])


def _unframe(blob: bytes, expect_kind: Optional[str] = None
             ) -> Tuple[Dict, memoryview]:
    if len(blob) < 10 or bytes(blob[:4]) != WIRE_MAGIC:
        raise WireError("not a PCM wire blob (bad magic)")
    version, hdr_len = struct.unpack("<HI", bytes(blob[4:10]))
    if version != WIRE_VERSION:
        raise WireError(f"wire version {version} != {WIRE_VERSION}")
    if len(blob) < 10 + hdr_len:
        raise WireError("truncated wire blob (header)")
    header = json.loads(bytes(blob[10:10 + hdr_len]).decode())
    body = memoryview(blob)[10 + hdr_len:]
    total = max((off + ln for off, ln in header["sections"].values()),
                default=0)
    if len(body) < total:
        raise WireError("truncated wire blob (payload)")
    if expect_kind is not None and header.get("kind") != expect_kind:
        raise WireError(
            f"wire blob kind {header.get('kind')!r} != {expect_kind!r}")
    return header, body


def _section(header: Dict, body: memoryview, name: str) -> bytes:
    off, ln = header["sections"][name]
    return bytes(body[off:off + ln])


# ------------------------------------------------------------ snapshots ----
def encode_snapshot(snap, chunk_bytes: int = 64 << 20) -> bytes:
    """Serialize a HOST_RAM :class:`ContextSnapshot` to a self-contained
    wire blob. Spilled snapshots must be unspilled first (the disk copy is
    node-local; the wire carries bytes, not paths)."""
    if getattr(snap, "spilled", False):
        raise WireError(
            f"snapshot {snap.key} is spilled to local disk; unspill before "
            "encoding for the wire")
    skel, recipes = _split_value(snap.value)
    table, side, payload = _pack_state(snap.host_state, chunk_bytes)
    header = {
        "recipes": recipes,
        "state": table,
        "meta": {
            "context_key": snap.key,
            "nbytes": int(snap.nbytes),
            "build_seconds": float(snap.build_seconds),
            "aot_seconds": float(snap.aot_seconds),
            "demote_seconds": float(snap.demote_seconds),
        },
    }
    sections = {
        "skeleton": pickle.dumps(skel, protocol=pickle.HIGHEST_PROTOCOL),
        "recipe": pickle.dumps(snap.recipe,
                               protocol=pickle.HIGHEST_PROTOCOL),
        "state_side": side,
        "state_payload": payload,
    }
    return _frame("snapshot", header, sections)


def decode_snapshot(blob: bytes):
    """Rebuild a :class:`ContextSnapshot` from a wire blob. Every array
    chunk is sha256-verified during unpack; recipe-encoded components are
    reconstructed via their loaders (compile-cache hits, no device
    state — ``restore_context`` promotes them exactly like an in-process
    peer template)."""
    from repro.core.context import ContextSnapshot

    header, body = _unframe(blob, expect_kind="snapshot")
    skel = pickle.loads(_section(header, body, "skeleton"))
    recipe = pickle.loads(_section(header, body, "recipe"))
    value = _join_value(skel, header["recipes"])
    host_state = _unpack_state(header["state"],
                               _section(header, body, "state_side"),
                               _section(header, body, "state_payload"))
    meta = header["meta"]
    return ContextSnapshot(recipe=recipe, value=value,
                           host_state=host_state,
                           nbytes=int(meta["nbytes"]),
                           build_seconds=float(meta["build_seconds"]),
                           aot_seconds=float(meta["aot_seconds"]),
                           demote_seconds=float(meta["demote_seconds"]))


# ------------------------------------------------------------ templates ----
def encode_template(recipe, clone, host_halves, device_tree,
                    nbytes: int, build_seconds: float, aot_seconds: float,
                    chunk_bytes: int = 64 << 20) -> bytes:
    """Serialize the METADATA half of a streamed (striped) transfer: the
    structural clone + host halves travel up front in one blob while the
    device half streams separately as verified chunks. ``device_tree`` (the
    donor's ``stripe_export_state`` output) is reduced to a shape/dtype
    spec tree — the receiver rebuilds the identical :class:`ChunkPlan`
    from specs alone, so donor and receiver agree on every chunk boundary
    without shipping the device bytes here."""
    import jax

    skel, recipes = _split_value(clone)
    table, side, payload = _pack_state(host_halves, chunk_bytes)
    spec_tree = jax.tree_util.tree_map(
        lambda a: jax.ShapeDtypeStruct(tuple(a.shape), np.dtype(a.dtype)),
        device_tree)
    header = {
        "recipes": recipes,
        "state": table,
        "meta": {
            "context_key": recipe.key(),
            "nbytes": int(nbytes),
            "build_seconds": float(build_seconds),
            "aot_seconds": float(aot_seconds),
            "chunk_bytes": int(chunk_bytes),
        },
    }
    sections = {
        "skeleton": pickle.dumps(skel, protocol=pickle.HIGHEST_PROTOCOL),
        "recipe": pickle.dumps(recipe, protocol=pickle.HIGHEST_PROTOCOL),
        "specs": pickle.dumps(spec_tree, protocol=pickle.HIGHEST_PROTOCOL),
        "state_side": side,
        "state_payload": payload,
    }
    return _frame("template", header, sections)


def decode_template_specs(blob: bytes) -> Tuple[Any, Dict]:
    """Cheap manager-side peek at a template blob: just the shape/dtype
    spec tree (to rebuild the ChunkPlan) and the scalar meta — no clone
    reconstruction, no host-half unpack. Used when the manager forwards a
    remote donor's template to a remote receiver: the blob passes through
    verbatim, but the manager still needs the plan to track the stripe."""
    header, body = _unframe(blob, expect_kind="template")
    spec_tree = pickle.loads(_section(header, body, "specs"))
    meta = header["meta"]
    return spec_tree, {
        "nbytes": int(meta["nbytes"]),
        "build_seconds": float(meta["build_seconds"]),
        "aot_seconds": float(meta["aot_seconds"]),
        "chunk_bytes": int(meta["chunk_bytes"]),
    }


def decode_template(blob: bytes) -> Dict[str, Any]:
    """Receiver half of :func:`encode_template`. Returns a dict with the
    rebuilt ``recipe``, ``clone``, ``host_halves``, the ``spec_tree`` to
    plan chunks over, and the scalar meta (``nbytes``, ``build_seconds``,
    ``aot_seconds``, ``chunk_bytes``)."""
    header, body = _unframe(blob, expect_kind="template")
    skel = pickle.loads(_section(header, body, "skeleton"))
    recipe = pickle.loads(_section(header, body, "recipe"))
    spec_tree = pickle.loads(_section(header, body, "specs"))
    clone = _join_value(skel, header["recipes"])
    host_halves = _unpack_state(header["state"],
                                _section(header, body, "state_side"),
                                _section(header, body, "state_payload"))
    meta = header["meta"]
    return {
        "recipe": recipe, "clone": clone, "host_halves": host_halves,
        "spec_tree": spec_tree, "nbytes": int(meta["nbytes"]),
        "build_seconds": float(meta["build_seconds"]),
        "aot_seconds": float(meta["aot_seconds"]),
        "chunk_bytes": int(meta["chunk_bytes"]),
    }
