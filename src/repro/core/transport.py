"""Length-prefixed socket transport for the PCM mailbox vocabulary.

The actor runtime's unit of communication is a mailbox message; this
module gives those messages a byte representation and a pair of dedicated
IO threads per connection, so a multi-GB context transfer never blocks a
compute mailbox and a slow receiver never blocks a donor's serving loop.

Frame layout (little-endian)::

    u32 header_len | u64 payload_len | JSON header | payload bytes

The JSON header always carries ``kind`` (the frame vocabulary — TASK,
FETCH, INSTALL, DONATE_CHUNKS, STRIPE_CHUNK, HEARTBEAT, ...) plus
kind-specific metadata (tokens, stripe ids, chunk refs, dtypes/shapes).
The payload is opaque bytes: a pickle, a ``repro.core.wire`` blob, or one
raw chunk of a striped transfer.

Each :class:`Connection` owns

* a **writer thread** draining an outbound queue. Queue items may be
  ready frames or *thunks* — callables evaluated on the writer thread —
  so expensive serialization (wire-encoding a snapshot, ``device_get`` of
  a template) runs on the IO thread, never on the manager lock or a
  donor's serving thread. Idle writers emit HEARTBEAT frames.
* a **reader thread** decoding frames into an ``on_frame`` callback and
  time-stamping ``last_seen`` (heartbeats included).

Liveness: EOF or a socket error fires ``on_lost`` exactly once; the
:class:`Router`'s monitor thread additionally declares a peer lost when
nothing (not even a heartbeat) arrived for ``lost_after`` seconds. Both
paths funnel into the same callback — the manager wires it to the
existing preemption path, so a ``kill -9``'d node is handled exactly
like a reclaimed opportunistic GPU.
"""

from __future__ import annotations

import json
import queue
import socket
import struct
import sys
import threading
import time
import traceback
from typing import Callable, Dict, List, Optional, Tuple

__all__ = ["Connection", "Listener", "Router", "TransportError",
           "read_frame", "write_frame"]

_HEADER = struct.Struct("<IQ")
# fail fast on garbage length prefixes instead of attempting a huge recv
_MAX_HEADER = 64 << 20
_MAX_PAYLOAD = 64 << 30

HEARTBEAT = "hb"


class TransportError(RuntimeError):
    """Connection-fatal framing or socket failure."""


def _recv_exact(sock: socket.socket, n: int) -> bytes:
    buf = bytearray(n)
    view = memoryview(buf)
    got = 0
    while got < n:
        r = sock.recv_into(view[got:], n - got)
        if r == 0:
            raise TransportError("connection closed mid-frame")
        got += r
    return bytes(buf)


def read_frame(sock: socket.socket) -> Tuple[str, Dict, bytes]:
    """Blocking read of one frame -> (kind, meta, payload)."""
    hdr_len, pay_len = _HEADER.unpack(_recv_exact(sock, _HEADER.size))
    if hdr_len > _MAX_HEADER or pay_len > _MAX_PAYLOAD:
        raise TransportError(
            f"frame too large (header {hdr_len}, payload {pay_len})")
    meta = json.loads(_recv_exact(sock, hdr_len).decode())
    payload = _recv_exact(sock, pay_len) if pay_len else b""
    kind = meta.pop("kind", "")
    return kind, meta, payload


def write_frame(sock: socket.socket, kind: str, meta: Dict,
                payload: bytes = b""):
    header = dict(meta or {})
    header["kind"] = kind
    hdr = json.dumps(header).encode()
    sock.sendall(_HEADER.pack(len(hdr), len(payload)))
    sock.sendall(hdr)
    if payload:
        sock.sendall(payload)


class Connection:
    """One bidirectional framed link with dedicated reader/writer threads.

    ``on_frame(conn, kind, meta, payload)`` runs on the reader thread for
    every non-heartbeat frame, in arrival order. ``on_lost(conn, reason)``
    fires at most once, from whichever thread detected the failure; a
    deliberate :meth:`close` never fires it.
    """

    def __init__(self, sock: socket.socket, name: str,
                 on_frame: Callable, on_lost: Optional[Callable] = None,
                 heartbeat: float = 1.0):
        try:
            sock.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
        except OSError:
            pass          # non-TCP socket (unix socketpair) — best effort
        self.sock = sock
        self.name = name
        self.heartbeat = float(heartbeat)
        self.last_seen = time.monotonic()
        self._on_frame = on_frame
        self._on_lost = on_lost
        self._out: "queue.SimpleQueue" = queue.SimpleQueue()
        self._lost_fired = False
        self._closed = False
        self._lock = threading.Lock()
        self._writer = threading.Thread(
            target=self._write_loop, name=f"pcm-tx-{name}", daemon=True)
        self._reader = threading.Thread(
            target=self._read_loop, name=f"pcm-rx-{name}", daemon=True)

    def start(self):
        self._writer.start()
        self._reader.start()

    # ---------------------------------------------------------- sending ----
    def send(self, kind: str, meta: Dict, payload: bytes = b""):
        """Queue one ready frame (returns immediately)."""
        self._out.put((kind, meta, payload))

    def send_lazy(self, thunk: Callable[[], Optional[tuple]]):
        """Queue a frame-producing thunk. It runs on the WRITER thread —
        the seam that keeps wire-encoding (pickles, ``device_get``s,
        pack_tree sha256 work) off compute threads and off the manager
        lock. Returning None sends nothing; an exception drops the frame
        (logged) but keeps the connection up."""
        self._out.put(thunk)

    def _write_loop(self):
        while not self._closed:
            try:
                item = self._out.get(timeout=self.heartbeat)
            except queue.Empty:
                item = (HEARTBEAT, {}, b"")
            if item is None:          # close() sentinel
                return
            if callable(item):
                try:
                    item = item()
                except BaseException:
                    traceback.print_exc(file=sys.stderr)
                    continue
                if item is None:
                    continue
            try:
                write_frame(self.sock, item[0], item[1], item[2])
            except BaseException as exc:
                self._lost(f"send failed: {exc}")
                return

    # -------------------------------------------------------- receiving ----
    def _read_loop(self):
        while not self._closed:
            try:
                kind, meta, payload = read_frame(self.sock)
            except BaseException as exc:
                self._lost(f"recv failed: {exc}")
                return
            self.last_seen = time.monotonic()
            if kind == HEARTBEAT:
                continue
            try:
                self._on_frame(self, kind, meta, payload)
            except BaseException:
                # a handler bug must not take the link down with it
                traceback.print_exc(file=sys.stderr)

    # --------------------------------------------------------- lifecycle ---
    def _lost(self, reason: str):
        with self._lock:
            if self._lost_fired or self._closed:
                return
            self._lost_fired = True
        cb = self._on_lost
        if cb is not None:
            try:
                cb(self, reason)
            except BaseException:
                traceback.print_exc(file=sys.stderr)

    def declare_lost(self, reason: str):
        """Externally declare the peer dead (heartbeat timeout) — fires
        ``on_lost`` through the same once-only gate as an IO failure."""
        self._lost(reason)
        self.close()

    def close(self):
        with self._lock:
            if self._closed:
                return
            self._closed = True
        self._out.put(None)
        try:
            self.sock.shutdown(socket.SHUT_RDWR)
        except OSError:
            pass
        try:
            self.sock.close()
        except OSError:
            pass

    @property
    def closed(self) -> bool:
        return self._closed

    def idle_for(self, now: Optional[float] = None) -> float:
        return (now if now is not None else time.monotonic()) \
            - self.last_seen


class Listener:
    """TCP accept loop. ``on_connect(sock, addr)`` runs on the accept
    thread for every inbound connection (the callee wraps it in a
    Connection once the HELLO arrives)."""

    def __init__(self, host: str, port: int, on_connect: Callable):
        self._sock = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
        self._sock.setsockopt(socket.SOL_SOCKET, socket.SO_REUSEADDR, 1)
        self._sock.bind((host, port))
        self._sock.listen(64)
        self.address: Tuple[str, int] = self._sock.getsockname()[:2]
        self._on_connect = on_connect
        self._closed = False
        self._thread = threading.Thread(
            target=self._accept_loop, name="pcm-listener", daemon=True)
        self._thread.start()

    def _accept_loop(self):
        while not self._closed:
            try:
                sock, addr = self._sock.accept()
            except OSError:
                return                      # closed
            try:
                self._on_connect(sock, addr)
            except BaseException:
                traceback.print_exc(file=sys.stderr)
                try:
                    sock.close()
                except OSError:
                    pass

    def close(self):
        self._closed = True
        try:
            self._sock.close()
        except OSError:
            pass


class Router:
    """Worker address book: worker_id -> Connection, plus the liveness
    monitor that declares silent peers lost after ``lost_after`` seconds
    without any inbound frame (heartbeats count). Loss detection is thus
    two-layered: socket EOF fires instantly (a killed process), the
    monitor catches wedged-but-open links (network partition)."""

    def __init__(self, lost_after: float = 10.0):
        self.lost_after = float(lost_after)
        self._conns: Dict[str, Connection] = {}
        self._lock = threading.Lock()
        self._closed = False
        self._monitor: Optional[threading.Thread] = None

    def register(self, worker_id: str, conn: Connection):
        with self._lock:
            self._conns[worker_id] = conn
            if self._monitor is None:
                self._monitor = threading.Thread(
                    target=self._monitor_loop, name="pcm-hb-monitor",
                    daemon=True)
                self._monitor.start()

    def unregister(self, worker_id: str) -> Optional[Connection]:
        with self._lock:
            return self._conns.pop(worker_id, None)

    def get(self, worker_id: str) -> Optional[Connection]:
        with self._lock:
            return self._conns.get(worker_id)

    def connections(self) -> List[Tuple[str, Connection]]:
        with self._lock:
            return list(self._conns.items())

    def _monitor_loop(self):
        # poll at a fraction of the deadline so detection latency stays a
        # small multiple of the configured timeout, not of the poll rate
        interval = max(0.05, self.lost_after / 4.0)
        while not self._closed:
            time.sleep(interval)
            now = time.monotonic()
            for wid, conn in self.connections():
                if not conn.closed and conn.idle_for(now) > self.lost_after:
                    conn.declare_lost(
                        f"no frames from {wid} for "
                        f"{conn.idle_for(now):.1f}s (declared lost)")

    def close(self):
        self._closed = True
        for _, conn in self.connections():
            conn.close()
        with self._lock:
            self._conns.clear()
