"""Chunk-granular context movement: plans, stripe lanes, and the
receiver-side reassembly buffer.

Every snapshot transfer used to move as one monolithic blob — a donor's
``export_context`` blocked its serving thread for the whole ``device_get``
and the receiver restored only once everything had landed. This module is
the machinery that breaks a template export into verifiable chunks so

* a donor ships a few chunks per mailbox turn and keeps serving between
  them (non-blocking export, ``repro.core.manager._handle_donate_chunks``),
* a receiver pulls disjoint chunk ranges concurrently from several
  sources — multiple warm donors, plus the node SnapshotPool for the
  immutable weight leaves (multi-source striping), and
* a single corrupt or lost lane degrades (reassign its refs to a healthy
  lane, or fall down the fetch ladder) without restarting the fetch.

The plan is DETERMINISTIC in the template's shapes alone: two donors
holding the same recipe's template compute byte-identical
:class:`ChunkPlan`s with zero coordination, so lane assignment is just
"donor *i* exports the refs assigned to lane *i*".

Integrity: every chunk travels with the sha256 of its bytes
(``chunk_digest``); the receiver re-hashes on delivery and a mismatch
surfaces as :class:`~repro.checkpoint.io.ChunkCorruptionError` on that
lane only.
"""

from __future__ import annotations

import threading
from dataclasses import dataclass
from typing import Any, Dict, List, Optional, Tuple

import numpy as np

from repro.checkpoint.io import (ChunkCorruptionError, _path_str,
                                 _sha256_array)

__all__ = ["ChunkRef", "ChunkPlan", "StripeBuffer", "ChunkCorruptionError",
           "assign_lanes", "chunk_digest", "pool_eligible"]


def _flatten_paths(tree) -> Tuple[List[Tuple[str, Any]], Any]:
    """Ordered ``[(flat_key, leaf), ...]`` plus the treedef — same
    "/"-joined key scheme as ``checkpoint.io`` but WITHOUT forcing leaves
    to numpy (donor-side leaves are device arrays; materializing one is a
    whole-payload ``device_get``, the exact stall chunking removes)."""
    import jax
    pairs = jax.tree_util.tree_flatten_with_path(tree)[0]
    treedef = jax.tree_util.tree_structure(tree)
    return ([("/".join(_path_str(p) for p in path), leaf)
             for path, leaf in pairs], treedef)


def chunk_digest(arr) -> str:
    return _sha256_array(np.asarray(arr))


def pool_eligible(key: str) -> bool:
    """Whether a chunk of this flat key may be served by a SnapshotPool
    stripe lane. Only the model weights qualify: ``params`` never mutate
    after build, so a pooled (demoted) snapshot's copy is bit-identical
    to every donor's. Everything else in a template (RNG, decode state)
    is synthesized or point-in-time and must come from a live donor."""
    return "params" in key.split("/")


@dataclass(frozen=True)
class ChunkRef:
    """One chunk of one leaf: rows ``[start, stop)`` along ``axis``.
    ``axis < 0`` marks a whole-leaf chunk (small or scalar leaves ship
    unsplit)."""

    key: str
    index: int                  # chunk index within the leaf
    count: int                  # total chunks of this leaf
    axis: int
    start: int
    stop: int

    @property
    def id(self) -> Tuple[str, int]:
        return (self.key, self.index)


class ChunkPlan:
    """Deterministic chunking of a whole pytree (a template's device half,
    a snapshot's host_state, ...): leaves bigger than ``chunk_bytes``
    split along their chunk axis (``axes`` maps flat-key prefixes to an
    axis — e.g. a paged KV page axis; default the leading axis) into
    ``<= chunk_bytes`` pieces, small leaves ride whole. ``refs`` is the
    global transfer order (leaf order, then chunk index); the treedef is
    carried so :meth:`assemble` rebuilds the exact structure — including
    list/tuple pytrees whose "/" keys alone would be ambiguous."""

    def __init__(self, tree, chunk_bytes: int = 64 << 20,
                 axes: Optional[Dict[str, int]] = None):
        self.chunk_bytes = int(chunk_bytes)
        flat, self.treedef = _flatten_paths(tree)
        self.leaf_keys: List[str] = [k for k, _ in flat]
        self.refs: List[ChunkRef] = []
        self.total_bytes = 0
        for key, leaf in flat:
            nbytes = int(getattr(leaf, "nbytes", 0) or 0)
            if not nbytes:
                # Spec-only leaves (e.g. jax.ShapeDtypeStruct on a remote
                # receiver rebuilding a donor's plan) carry no buffer, so
                # derive the size from shape x itemsize — the plan must be
                # a pure function of shapes for cross-process determinism.
                spec_shape = tuple(getattr(leaf, "shape", ()) or ())
                spec_dtype = getattr(leaf, "dtype", None)
                if spec_dtype is not None:
                    nbytes = int(np.prod(spec_shape, dtype=np.int64) *
                                 np.dtype(spec_dtype).itemsize)
            self.total_bytes += nbytes
            shape = getattr(leaf, "shape", ())
            axis = 0
            for prefix, ax in (axes or {}).items():
                if key == prefix or key.startswith(prefix + "/"):
                    axis = int(ax)
                    break
            dim = shape[axis] if shape else 0
            if nbytes <= self.chunk_bytes or dim <= 1:
                self.refs.append(ChunkRef(key=key, index=0, count=1,
                                          axis=-1, start=0, stop=0))
                continue
            row_bytes = max(1, nbytes // dim)
            rows = max(1, min(dim, self.chunk_bytes // row_bytes))
            n = -(-dim // rows)
            for i in range(n):
                self.refs.append(ChunkRef(
                    key=key, index=i, count=n, axis=axis,
                    start=i * rows, stop=min(dim, (i + 1) * rows)))

    def extract(self, flat: Dict[str, Any], ref: ChunkRef):
        """Slice ``ref``'s chunk out of a flat key->array map (device or
        host arrays — slicing a device array stays on device; the caller
        decides when the ``device_get`` happens)."""
        arr = flat[ref.key]
        if ref.axis < 0:
            return arr
        sel = (slice(None),) * ref.axis
        return arr[sel + (slice(ref.start, ref.stop),)]

    @staticmethod
    def flat_map(tree) -> Dict[str, Any]:
        return dict(_flatten_paths(tree)[0])


def assign_lanes(refs: List[ChunkRef], n_donor_lanes: int,
                 n_pool_lanes: int = 0) -> List[List[ChunkRef]]:
    """Split a plan's refs across stripe lanes: donor lanes first
    (``0 .. n_donor_lanes-1``), then pool lanes. Pool-eligible refs
    (immutable ``params``) round-robin over ALL lanes; everything else
    only over donor lanes. Pure function of the plan — every participant
    computes the same assignment independently."""
    total = n_donor_lanes + n_pool_lanes
    if n_donor_lanes < 1:
        raise ValueError("striping requires at least one donor lane")
    lanes: List[List[ChunkRef]] = [[] for _ in range(total)]
    rr_all = rr_donor = 0
    for ref in refs:
        if pool_eligible(ref.key):
            lanes[rr_all % total].append(ref)
            rr_all += 1
        else:
            lanes[rr_donor % n_donor_lanes].append(ref)
            rr_donor += 1
    return lanes


class StripeBuffer:
    """Receiver-side accumulation of one striped template transfer.

    Donor lanes (and the optional pool lane) deliver verified chunks
    concurrently from their own threads; the buffer verifies each
    delivery against its claimed digest, assembles a leaf eagerly the
    moment its last chunk lands (freeing the chunk pieces — the
    double-buffering half of the overlapped restore), and reports
    completion once the primary lane's template metadata AND every
    expected ref have arrived. ``assemble()`` then rebuilds the device
    half via the plan's treedef and merges it into the host halves.
    """

    def __init__(self):
        self._lock = threading.Lock()
        self._pending: Dict[str, Dict[int, np.ndarray]] = {}
        self._leaves: Dict[str, np.ndarray] = {}
        self._expected: Optional[Dict[Tuple[str, int], ChunkRef]] = None
        self._delivered: set = set()
        self.plan: Optional[ChunkPlan] = None
        self.clone = None
        self.host_halves: Optional[Dict[str, Any]] = None
        self.nbytes = 0
        self.build_seconds = 0.0
        self.aot_seconds = 0.0
        self.lane_seconds: Dict[int, float] = {}
        self.chunks_delivered = 0
        self.install_posted = False     # guarded by the manager's lock

    # ------------------------------------------------------------ filling --
    def set_template(self, plan: ChunkPlan, clone, host_halves: Dict,
                     nbytes: int, build_seconds: float, aot_seconds: float):
        """Primary-lane metadata: the deterministic plan (for the expected
        ref set + treedef), the structural clone sharing the donor's AOT
        executables, and the synthesized host halves of each component's
        template."""
        with self._lock:
            self.plan = plan
            self.clone = clone
            self.host_halves = host_halves
            self.nbytes = nbytes
            self.build_seconds = build_seconds
            self.aot_seconds = aot_seconds
            self._expected = {r.id: r for r in plan.refs}

    def deliver(self, ref: ChunkRef, array, sha: str, lane: int = 0):
        """Accept one chunk from a lane, re-hashing to verify. Raises
        ChunkCorruptionError on digest mismatch (the caller fails that
        LANE, not the whole stripe)."""
        arr = np.asarray(array)
        if _sha256_array(arr) != sha:
            raise ChunkCorruptionError(
                f"stripe chunk {ref.index} of {ref.key!r} from lane {lane} "
                "failed verification")
        with self._lock:
            if ref.id in self._delivered:
                return
            self._delivered.add(ref.id)
            self.chunks_delivered += 1
            if ref.count == 1 and ref.axis < 0:
                self._leaves[ref.key] = arr
                return
            parts = self._pending.setdefault(ref.key, {})
            parts[ref.index] = arr
            if len(parts) == ref.count:     # leaf complete: assemble eagerly
                self._leaves[ref.key] = np.concatenate(
                    [parts[i] for i in range(ref.count)], axis=ref.axis)
                del self._pending[ref.key]

    def add_lane_seconds(self, lane: int, seconds: float):
        with self._lock:
            self.lane_seconds[lane] = \
                self.lane_seconds.get(lane, 0.0) + seconds

    # ----------------------------------------------------------- querying --
    def complete(self) -> bool:
        with self._lock:
            return (self._expected is not None
                    and len(self._delivered) >= len(self._expected))

    def missing_refs(self, assigned: List[ChunkRef]) -> List[ChunkRef]:
        """The subset of a lost lane's refs not yet delivered — what a
        surviving lane must re-export."""
        with self._lock:
            return [r for r in assigned if r.id not in self._delivered]

    def delivered_ids(self) -> List[Tuple[str, int]]:
        """Ref ids verified so far — what a remote receiver reports back
        on a lane failure so the manager-side stripe state reconciles to
        the receiver's (authoritative) view before reassigning refs."""
        with self._lock:
            return list(self._delivered)

    @property
    def export_seconds(self) -> float:
        """Donor-side cost of the transfer: the slowest lane's cumulative
        export time (lanes ran concurrently) — the striped analogue of the
        monolithic snapshot's ``demote_seconds``."""
        with self._lock:
            return max(self.lane_seconds.values(), default=0.0)

    # ----------------------------------------------------------- assembly --
    def assemble(self) -> Dict[str, Any]:
        """Rebuild the per-component host_state: unflatten the device half
        from the assembled leaves via the plan's treedef, then merge into
        the host halves. Called on the receiver's thread once complete."""
        import jax
        with self._lock:
            if self._expected is None or \
                    len(self._delivered) < len(self._expected):
                raise RuntimeError("stripe transfer incomplete")
            leaves = [self._leaves[k] for k in self.plan.leaf_keys]
            device_half = jax.tree_util.tree_unflatten(
                self.plan.treedef, leaves)
            host_state: Dict[str, Any] = {}
            for name, half in (self.host_halves or {}).items():
                merged = dict(half)
                merged.update(device_half.get(name, {}))
                host_state[name] = merged
            self._leaves = {}
            return host_state
