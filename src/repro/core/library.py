"""The Library — the persistent executor that holds materialized contexts.

Mirrors the TaskVine library process (paper §3): it registers a function's
context recipe once, materializes it (builder runs in this process's
address space), then executes every subsequent invocation against the
resident context. On TPU the materialization includes AOT compilation, so
the Library doubles as a compile cache: the (weights, executables, KV pool)
triple survives across tasks.

``current_context()`` is the in-task accessor — the JAX-world analogue of
the paper's ``load_variable_from_serverless``.
"""

from __future__ import annotations

import contextvars
import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional

from repro.core.context import Context, ContextRecipe, materialize

_current: contextvars.ContextVar = contextvars.ContextVar(
    "repro_pcm_context", default=None)


def current_context() -> Any:
    """Inside a PCM task: the context value built by the recipe's builder."""
    ctx = _current.get()
    if ctx is None:
        raise RuntimeError("no PCM context installed — is this function "
                           "running under a Library / PCMManager?")
    return ctx.value


def load_variable_from_context(name: str) -> Any:
    """Paper Fig. 5 compatibility shim: context builders return dicts."""
    value = current_context()
    if not isinstance(value, dict) or name not in value:
        raise KeyError(f"context has no variable {name!r}")
    return value[name]


@dataclass
class InvocationRecord:
    task_id: str
    ctx_key: str
    seconds: float
    cold: bool


class Library:
    """One per worker. Materializes recipes once; executes invocations."""

    def __init__(self, worker_id: str = "local"):
        self.worker_id = worker_id
        self._contexts: Dict[str, Context] = {}
        self.records: List[InvocationRecord] = []
        self.build_seconds_total = 0.0

    # ---------------------------------------------------------- contexts --
    def has(self, key: str) -> bool:
        return key in self._contexts

    def ensure(self, recipe: ContextRecipe) -> Context:
        """Materialize if absent (the one-time startup); return resident."""
        key = recipe.key()
        if key not in self._contexts:
            ctx = materialize(recipe, self.worker_id)
            self._contexts[key] = ctx
            self.build_seconds_total += ctx.build_seconds
        return self._contexts[key]

    def install(self, ctx: Context):
        """Adopt a context transferred from a peer (P2P bootstrap)."""
        self._contexts[ctx.key] = ctx

    def evict(self, key: str) -> Optional[Context]:
        return self._contexts.pop(key, None)

    def evict_all(self):
        self._contexts.clear()

    def context(self, key: str) -> Context:
        return self._contexts[key]

    @property
    def resident_keys(self):
        return set(self._contexts)

    # -------------------------------------------------------- invocation --
    def invoke(self, fn: Callable, args: tuple = (), kwargs: dict = None,
               recipe: Optional[ContextRecipe] = None,
               task_id: str = "") -> Any:
        """Execute fn with the recipe's context installed.

        ``cold`` in the record marks invocations that had to materialize the
        context first (the startup the paper amortizes away)."""
        kwargs = kwargs or {}
        t0 = time.monotonic()
        cold = False
        token = None
        if recipe is not None:
            cold = not self.has(recipe.key())
            ctx = self.ensure(recipe)
            ctx.touch()
            token = _current.set(ctx)
        try:
            return fn(*args, **kwargs)
        finally:
            if token is not None:
                _current.reset(token)
            self.records.append(InvocationRecord(
                task_id=task_id, ctx_key=recipe.key() if recipe else "",
                seconds=time.monotonic() - t0, cold=cold))
