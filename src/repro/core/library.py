"""The Library — the persistent executor that holds materialized contexts.

Mirrors the TaskVine library process (paper §3): it registers a function's
context recipe once, materializes it (builder runs in this process's
address space), then executes every subsequent invocation against the
resident context. On TPU the materialization includes AOT compilation, so
the Library doubles as a compile cache: the (weights, executables, KV pool)
triple survives across tasks.

In the concurrent runtime each Library is owned by ONE worker actor thread
(see ``repro.core.manager``): all builds, invocations and demotions happen
on that thread, serialized by the worker's mailbox. The Library is also
the seam for physical tier movement — ``ensure`` prefers promoting a
demoted snapshot from the node :class:`~repro.core.store.SnapshotPool`
(restore cost: one host/disk -> device transfer, zero builder calls, zero
compiles) over re-running the builder, and ``demote``/``demote_all`` push
resident contexts the other way when a worker idles or loses its device.

A task may hold SEVERAL named contexts at once (e.g. a verifier engine and
a reranker engine); ``invoke`` installs the whole mapping and
``load_variable_from_context`` resolves both unqualified variable names
(``"engine"``, searched across the installed contexts) and qualified
``"ctxname.var"`` references.

``current_context()`` is the in-task accessor — the JAX-world analogue of
the paper's ``load_variable_from_serverless``.
"""

from __future__ import annotations

import contextvars
import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Mapping, Optional, Set

from repro.core.context import (Context, ContextRecipe, materialize,
                                restore_context, snapshot_context)
from repro.core.transfer import FetchSource

_current: contextvars.ContextVar = contextvars.ContextVar(
    "repro_pcm_context", default=None)


def current_context() -> Any:
    """Inside a PCM task: the context value built by the recipe's builder.

    With a single installed context this is that context's value; with
    multiple named contexts it is a ``{name: value}`` mapping.
    """
    installed: Optional[Dict[str, Context]] = _current.get()
    if not installed:
        raise RuntimeError("no PCM context installed — is this function "
                           "running under a Library / PCMManager?")
    if len(installed) == 1:
        return next(iter(installed.values())).value
    return {name: ctx.value for name, ctx in installed.items()}


def load_variable_from_context(name: str) -> Any:
    """Resolve a context variable for the running task.

    ``"var"``          searched across every installed context whose value
                       is a dict; must match exactly one.
    ``"ctxname.var"``  looked up in the named context (multi-context tasks).
    """
    installed: Optional[Dict[str, Context]] = _current.get()
    if not installed:
        raise RuntimeError("no PCM context installed — is this function "
                           "running under a Library / PCMManager?")
    if "." in name:
        ctx_name, var = name.split(".", 1)
        if ctx_name in installed:
            value = installed[ctx_name].value
            if isinstance(value, dict) and var in value:
                return value[var]
            raise KeyError(f"context {ctx_name!r} has no variable {var!r}")
    hits = [(cname, ctx.value[name]) for cname, ctx in installed.items()
            if isinstance(ctx.value, dict) and name in ctx.value]
    if len(hits) == 1:
        return hits[0][1]
    if not hits:
        raise KeyError(f"no installed context has variable {name!r} "
                       f"(contexts: {sorted(installed)})")
    raise KeyError(f"variable {name!r} is ambiguous across contexts "
                   f"{sorted(c for c, _ in hits)} — qualify as "
                   f"'<context>.{name}'")


@dataclass
class InvocationRecord:
    task_id: str
    ctx_key: str
    seconds: float
    cold: bool


class Library:
    """One per worker. Materializes recipes once; executes invocations."""

    def __init__(self, worker_id: str = "local", snapshots=None,
                 streamed: bool = False, fetch_source_limit: int = 4096):
        self.worker_id = worker_id
        self.snapshots = snapshots     # node SnapshotPool (may be None)
        # streamed=True: DISK promotions stream spill entries straight to
        # device (read+verify one thread, device_put the other) instead of
        # materializing the whole host snapshot first
        self.streamed = streamed
        self.fetch_source_limit = int(fetch_source_limit)
        self._contexts: Dict[str, Context] = {}
        self.pinned: Set[str] = set()
        self.records: List[InvocationRecord] = []
        self.build_seconds_total = 0.0
        self.aot_seconds_total = 0.0   # executable warm-up inside builds
        self.builder_calls = 0         # full materializations (cold builds)
        self.restores = 0              # snapshot promotions (no builder)
        self.restore_seconds_total = 0.0
        self.demotions = 0
        self.peer_installs = 0         # contexts adopted from a P2P donor
        self.peer_exports = 0          # templates exported to receivers
        self.peer_install_seconds = 0.0
        # the ACTUAL source of every acquisition this Library performed
        # (POOL/DISK/BUILD via ensure, PEER via adopt) — the execution-side
        # complement of the scheduler's fetch_log decisions. Bounded: a
        # long-lived worker trims the oldest entries past
        # ``fetch_source_limit`` (kept a list, not a deque, so existing
        # slicing/comparison call sites are untouched).
        self.fetch_sources: List[FetchSource] = []
        # per-stage (disk/h2d) timings observed during streamed restores,
        # as (stage, nbytes, seconds) — drained by the manager into
        # TransferPlanner.observe_stage for pipeline-cost calibration
        self.stage_observations: List[tuple] = []

    # ---------------------------------------------------------- contexts --
    def has(self, key: str) -> bool:
        return key in self._contexts

    def ensure(self, recipe: ContextRecipe) -> Context:
        """Return the resident context, RESTORING it from the node snapshot
        pool when a demoted copy exists (promotion: ``jax.device_put`` of
        the host/disk snapshot — zero builder calls, zero compiles), and
        materializing it from scratch only when it does not (the one-time
        startup).

        Materialization AOT-compiles any engines in the built value (see
        ``repro.core.context.materialize``), so the resident context holds
        weights + KV pools + compiled executables: tasks executed against
        it never pay a compile."""
        key = recipe.key()
        if key not in self._contexts:
            ctx = None
            if self.snapshots is not None:
                snap = self.snapshots.take(key)
                if snap is not None:
                    from_disk = snap.spilled
                    ctx = restore_context(
                        snap, self.worker_id,
                        spill_store=self.snapshots.spill_store(),
                        streamed=self.streamed)
                    self.restores += 1
                    self.restore_seconds_total += ctx.restore_seconds
                    self.snapshots.restore_seconds += ctx.restore_seconds
                    for stage, info in (ctx.stage_seconds or {}).items():
                        self.stage_observations.append(
                            (stage, info[0], info[1]))
                    self._record_source(
                        FetchSource.DISK if from_disk else FetchSource.POOL)
            if ctx is None:
                ctx = materialize(recipe, self.worker_id)
                self.builder_calls += 1
                self.build_seconds_total += ctx.build_seconds
                self.aot_seconds_total += ctx.aot_seconds
                self._record_source(
                    FetchSource.FS if recipe.transfer_bytes > 0
                    else FetchSource.BUILD)
            self._contexts[key] = ctx
        return self._contexts[key]

    def demote(self, key: str, force: bool = False):
        """Physically demote one resident context DEVICE -> HOST_RAM: pull
        its device state into a ContextSnapshot and hand it to the node
        snapshot pool (which may later spill it to LOCAL_DISK). Returns the
        snapshot, or None when the key is absent/pinned (pins are a
        device-residency promise; pass ``force`` when the device itself is
        being lost). A Library without a snapshot pool cannot demote —
        refusing up front, NOT evicting, so the context is never destroyed
        by a demotion that has nowhere to put it."""
        if self.snapshots is None:
            return None
        ctx = self.evict(key, force=force)
        if ctx is None:
            return None
        snap = snapshot_context(ctx)
        self.snapshots.put(snap)
        self.demotions += 1
        return snap

    def demote_all(self, force: bool = False):
        """Demote every resident context (worker retirement: the device is
        being reclaimed, so even pinned contexts move to host)."""
        for key in list(self._contexts):
            self.demote(key, force=force)

    def install(self, ctx: Context):
        """Make a context resident without building it here."""
        self._contexts[ctx.key] = ctx

    def adopt(self, ctx: Context):
        """Adopt a context restored from a peer-donated template snapshot
        (P2P bootstrap): resident with zero builder calls and zero
        compiles, at one device_put of transfer cost. Counted under
        ``peer_install_seconds`` only — ``restore_seconds_total`` stays
        pool/disk promotions, so the two never double-count."""
        self.install(ctx)
        self.peer_installs += 1
        self.peer_install_seconds += ctx.restore_seconds
        self._record_source(FetchSource.PEER)

    def _record_source(self, source: FetchSource):
        self.fetch_sources.append(source)
        if len(self.fetch_sources) > self.fetch_source_limit:
            del self.fetch_sources[:-self.fetch_source_limit]

    def pin(self, key: str):
        self.pinned.add(key)

    def unpin(self, key: str):
        self.pinned.discard(key)

    def evict(self, key: str, force: bool = False) -> Optional[Context]:
        if key in self.pinned and not force:
            return None
        return self._contexts.pop(key, None)

    def evict_all(self, force: bool = False):
        if force or not self.pinned:
            self._contexts.clear()
        else:
            self._contexts = {k: v for k, v in self._contexts.items()
                              if k in self.pinned}

    def context(self, key: str) -> Context:
        return self._contexts[key]

    @property
    def resident_keys(self):
        return set(self._contexts)

    # -------------------------------------------------------- invocation --
    def invoke(self, fn: Callable, args: tuple = (), kwargs: dict = None,
               recipe: Optional[ContextRecipe] = None,
               recipes: Optional[Mapping[str, ContextRecipe]] = None,
               task_id: str = "") -> Any:
        """Execute fn with the recipes' contexts installed.

        ``recipes`` is an ordered ``{name: recipe}`` mapping (multi-context
        tasks); ``recipe`` is the single-context shorthand, installed under
        its own ``recipe.name``. ``cold`` in the record marks invocations
        that had to materialize at least one context first (the startup the
        paper amortizes away)."""
        kwargs = kwargs or {}
        named: Dict[str, ContextRecipe] = dict(recipes or {})
        if recipe is not None and recipe.key() not in {
                r.key() for r in named.values()}:
            named.setdefault(recipe.name, recipe)
        t0 = time.monotonic()
        cold = False
        token = None
        if named:
            installed: Dict[str, Context] = {}
            for cname, rec in named.items():
                cold = cold or not self.has(rec.key())
                ctx = self.ensure(rec)
                ctx.touch()
                installed[cname] = ctx
            token = _current.set(installed)
        try:
            return fn(*args, **kwargs)
        finally:
            if token is not None:
                _current.reset(token)
            self.records.append(InvocationRecord(
                task_id=task_id,
                ctx_key=",".join(r.key() for r in named.values()),
                seconds=time.monotonic() - t0, cold=cold))
