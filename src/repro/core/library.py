"""The Library — the persistent executor that holds materialized contexts.

Mirrors the TaskVine library process (paper §3): it registers a function's
context recipe once, materializes it (builder runs in this process's
address space), then executes every subsequent invocation against the
resident context. On TPU the materialization includes AOT compilation, so
the Library doubles as a compile cache: the (weights, executables, KV pool)
triple survives across tasks.

A task may hold SEVERAL named contexts at once (e.g. a verifier engine and
a reranker engine); ``invoke`` installs the whole mapping and
``load_variable_from_context`` resolves both unqualified variable names
(``"engine"``, searched across the installed contexts) and qualified
``"ctxname.var"`` references.

``current_context()`` is the in-task accessor — the JAX-world analogue of
the paper's ``load_variable_from_serverless``.
"""

from __future__ import annotations

import contextvars
import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Mapping, Optional, Set

from repro.core.context import Context, ContextRecipe, materialize

_current: contextvars.ContextVar = contextvars.ContextVar(
    "repro_pcm_context", default=None)


def current_context() -> Any:
    """Inside a PCM task: the context value built by the recipe's builder.

    With a single installed context this is that context's value; with
    multiple named contexts it is a ``{name: value}`` mapping.
    """
    installed: Optional[Dict[str, Context]] = _current.get()
    if not installed:
        raise RuntimeError("no PCM context installed — is this function "
                           "running under a Library / PCMManager?")
    if len(installed) == 1:
        return next(iter(installed.values())).value
    return {name: ctx.value for name, ctx in installed.items()}


def load_variable_from_context(name: str) -> Any:
    """Resolve a context variable for the running task.

    ``"var"``          searched across every installed context whose value
                       is a dict; must match exactly one.
    ``"ctxname.var"``  looked up in the named context (multi-context tasks).
    """
    installed: Optional[Dict[str, Context]] = _current.get()
    if not installed:
        raise RuntimeError("no PCM context installed — is this function "
                           "running under a Library / PCMManager?")
    if "." in name:
        ctx_name, var = name.split(".", 1)
        if ctx_name in installed:
            value = installed[ctx_name].value
            if isinstance(value, dict) and var in value:
                return value[var]
            raise KeyError(f"context {ctx_name!r} has no variable {var!r}")
    hits = [(cname, ctx.value[name]) for cname, ctx in installed.items()
            if isinstance(ctx.value, dict) and name in ctx.value]
    if len(hits) == 1:
        return hits[0][1]
    if not hits:
        raise KeyError(f"no installed context has variable {name!r} "
                       f"(contexts: {sorted(installed)})")
    raise KeyError(f"variable {name!r} is ambiguous across contexts "
                   f"{sorted(c for c, _ in hits)} — qualify as "
                   f"'<context>.{name}'")


@dataclass
class InvocationRecord:
    task_id: str
    ctx_key: str
    seconds: float
    cold: bool


class Library:
    """One per worker. Materializes recipes once; executes invocations."""

    def __init__(self, worker_id: str = "local"):
        self.worker_id = worker_id
        self._contexts: Dict[str, Context] = {}
        self.pinned: Set[str] = set()
        self.records: List[InvocationRecord] = []
        self.build_seconds_total = 0.0
        self.aot_seconds_total = 0.0   # executable warm-up inside builds

    # ---------------------------------------------------------- contexts --
    def has(self, key: str) -> bool:
        return key in self._contexts

    def ensure(self, recipe: ContextRecipe) -> Context:
        """Materialize if absent (the one-time startup); return resident.

        Materialization AOT-compiles any engines in the built value (see
        ``repro.core.context.materialize``), so the resident context holds
        weights + KV pools + compiled executables: tasks executed against
        it never pay a compile."""
        key = recipe.key()
        if key not in self._contexts:
            ctx = materialize(recipe, self.worker_id)
            self._contexts[key] = ctx
            self.build_seconds_total += ctx.build_seconds
            self.aot_seconds_total += ctx.aot_seconds
        return self._contexts[key]

    def install(self, ctx: Context):
        """Adopt a context transferred from a peer (P2P bootstrap)."""
        self._contexts[ctx.key] = ctx

    def pin(self, key: str):
        self.pinned.add(key)

    def unpin(self, key: str):
        self.pinned.discard(key)

    def evict(self, key: str, force: bool = False) -> Optional[Context]:
        if key in self.pinned and not force:
            return None
        return self._contexts.pop(key, None)

    def evict_all(self, force: bool = False):
        if force or not self.pinned:
            self._contexts.clear()
        else:
            self._contexts = {k: v for k, v in self._contexts.items()
                              if k in self.pinned}

    def context(self, key: str) -> Context:
        return self._contexts[key]

    @property
    def resident_keys(self):
        return set(self._contexts)

    # -------------------------------------------------------- invocation --
    def invoke(self, fn: Callable, args: tuple = (), kwargs: dict = None,
               recipe: Optional[ContextRecipe] = None,
               recipes: Optional[Mapping[str, ContextRecipe]] = None,
               task_id: str = "") -> Any:
        """Execute fn with the recipes' contexts installed.

        ``recipes`` is an ordered ``{name: recipe}`` mapping (multi-context
        tasks); ``recipe`` is the single-context shorthand, installed under
        its own ``recipe.name``. ``cold`` in the record marks invocations
        that had to materialize at least one context first (the startup the
        paper amortizes away)."""
        kwargs = kwargs or {}
        named: Dict[str, ContextRecipe] = dict(recipes or {})
        if recipe is not None and recipe.key() not in {
                r.key() for r in named.values()}:
            named.setdefault(recipe.name, recipe)
        t0 = time.monotonic()
        cold = False
        token = None
        if named:
            installed: Dict[str, Context] = {}
            for cname, rec in named.items():
                cold = cold or not self.has(rec.key())
                ctx = self.ensure(rec)
                ctx.touch()
                installed[cname] = ctx
            token = _current.set(installed)
        try:
            return fn(*args, **kwargs)
        finally:
            if token is not None:
                _current.reset(token)
            self.records.append(InvocationRecord(
                task_id=task_id,
                ctx_key=",".join(r.key() for r in named.values()),
                seconds=time.monotonic() - t0, cold=cold))
