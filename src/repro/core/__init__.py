"""Pervasive Context Management — the paper's primary contribution.

The user entry point is the **PCMClient session API** (api.py): declare
contexts as first-class handles (``client.context`` -> pin / release /
warm_up / residency), attach one or several named contexts to tasks
(``@client.task(contexts={...})``), and submit work as Futures
(``client.submit``) or FutureBatches (``client.map`` ->
``as_completed()`` / ``gather()``, per-future timeouts and callbacks,
priority hints). The client drives a pluggable **ExecutionBackend**
(backend.py): ``PCMManager`` runs tasks live (real JAX inference);
``SimulatorBackend`` dry-runs the identical application against the
discrete-event cluster model — swap one constructor argument to go from
serving to paper-figure simulation.

The live backend is a **concurrent actor runtime**: every worker is a
thread with a mailbox owning its Library/ContextStore; the scheduler runs
behind one lock fed by runtime events; Futures resolve on condition
variables. Context tier movement is physical — demotion snapshots params
and engine state to host RAM (``jax.device_get``), spills to local disk
through ``checkpoint/io``, and promotion restores with zero builder calls
and zero XLA compiles (see the residency state diagram in store.py).

Module map:
  context.py   ContextRecipe / Context / ContextSnapshot (first-class LLM
               contexts through their whole residency lifecycle)
  store.py     tiered per-worker residency + pinning (agnostic/partial/
               full, TierFullError on pin-blocked admission) + the node
               SnapshotPool (physical HOST_RAM/LOCAL_DISK tiers)
  library.py   per-worker executor holding materialized (named) contexts;
               restore-over-rebuild, demote to the pool
  transfer.py  shared-FS vs peer-to-peer bootstrap planning + promotion
               (restore) bandwidth modeling
  scheduler.py context-aware placement (DEVICE > HOST_RAM > LOCAL_DISK >
               cold ladder, multi-context, contextless, priority hints),
               requeue-on-preemption, stragglers
  factory.py   reactive opportunistic pool reconciliation
  manager.py   live concurrent runtime (worker actor threads + mailboxes,
               real JAX execution, physical preemption demotion) + Future
  backend.py   ExecutionBackend protocol + SimulatorBackend dry-run
  api.py       PCMClient / ContextHandle (pin, warm_up, demote, residency)
               / FutureBatch (+ legacy @context_app shim, paper Fig. 5)
"""

from repro.core.api import (ContextHandle, FutureBatch, PCMClient,
                            context_app, get_default_client,
                            get_default_manager, load_context, make_recipe,
                            set_default_manager)
from repro.core.backend import (ExecutionBackend, LiveBackend, SimTaskResult,
                                SimulatorBackend)
from repro.core.context import (Context, ContextRecipe, ContextSnapshot,
                                materialize, restore_context,
                                snapshot_context)
from repro.core.library import (Library, current_context,
                                load_variable_from_context)
from repro.core.manager import Future, PCMManager
from repro.core.scheduler import (Action, Completion, ContextAwareScheduler,
                                  Task, WorkerPhase)
from repro.core.store import (ContextMode, ContextStore, SnapshotPool, Tier,
                              TierFullError)
from repro.core.transfer import TransferPlan, TransferPlanner

__all__ = [
    "ContextHandle", "FutureBatch", "PCMClient", "context_app",
    "get_default_client", "get_default_manager", "load_context",
    "make_recipe", "set_default_manager", "ExecutionBackend", "LiveBackend",
    "SimTaskResult", "SimulatorBackend", "Context", "ContextRecipe",
    "ContextSnapshot", "materialize", "restore_context", "snapshot_context",
    "Library", "current_context",
    "load_variable_from_context", "Future", "PCMManager", "Action",
    "Completion", "ContextAwareScheduler", "Task", "WorkerPhase",
    "ContextMode", "ContextStore", "SnapshotPool", "Tier", "TierFullError",
    "TransferPlan", "TransferPlanner",
]
