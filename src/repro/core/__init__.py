"""Pervasive Context Management — the paper's primary contribution.

context.py   ContextRecipe / Context (first-class LLM contexts)
store.py     tiered per-worker residency (agnostic/partial/full modes)
library.py   persistent executor holding materialized contexts
transfer.py  shared-FS vs peer-to-peer bootstrap planning
scheduler.py context-aware placement, requeue-on-preemption, stragglers
factory.py   reactive opportunistic pool reconciliation
manager.py   live in-process runtime (real JAX execution)
api.py       @context_app / load_context user API (paper Fig. 5)
"""

from repro.core.api import (context_app, get_default_manager, load_context,
                            make_recipe, set_default_manager)
from repro.core.context import Context, ContextRecipe, materialize
from repro.core.library import (Library, current_context,
                                load_variable_from_context)
from repro.core.manager import Future, PCMManager
from repro.core.scheduler import (Action, Completion, ContextAwareScheduler,
                                  Task, WorkerPhase)
from repro.core.store import ContextMode, ContextStore, Tier
from repro.core.transfer import TransferPlan, TransferPlanner

__all__ = [
    "context_app", "get_default_manager", "load_context", "make_recipe",
    "set_default_manager", "Context", "ContextRecipe", "materialize",
    "Library", "current_context", "load_variable_from_context", "Future",
    "PCMManager", "Action", "Completion", "ContextAwareScheduler", "Task",
    "WorkerPhase", "ContextMode", "ContextStore", "Tier", "TransferPlan",
    "TransferPlanner",
]
