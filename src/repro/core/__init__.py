"""Pervasive Context Management — the paper's primary contribution.

The user entry point is the **PCMClient session API** (api.py): declare
contexts as first-class handles (``client.context`` -> pin / release /
warm_up / residency), attach one or several named contexts to tasks
(``@client.task(contexts={...})``), and submit work as Futures
(``client.submit``) or FutureBatches (``client.map`` ->
``as_completed()`` / ``gather()``, per-future timeouts and callbacks,
priority hints). The client drives a pluggable **ExecutionBackend**
(backend.py): ``PCMManager`` runs tasks live (real JAX inference);
``SimulatorBackend`` dry-runs the identical application against the
discrete-event cluster model — swap one constructor argument to go from
serving to paper-figure simulation.

The live backend is a **concurrent actor runtime**: every worker is a
thread with a mailbox owning its Library/ContextStore; the scheduler runs
behind one lock fed by runtime events; Futures resolve on condition
variables. Context tier movement is physical — demotion snapshots params
and engine state to host RAM (``jax.device_get``), spills to local disk
through ``checkpoint/io``, and promotion restores with zero builder calls
and zero XLA compiles (see the residency state diagram in store.py).

Module map:
  context.py   ContextRecipe / Context / ContextSnapshot (first-class LLM
               contexts through their whole residency lifecycle)
  store.py     tiered per-worker residency + pinning (agnostic/partial/
               full, TierFullError on pin-blocked admission) + the node
               SnapshotPool (physical HOST_RAM/LOCAL_DISK tiers)
  library.py   per-worker executor holding materialized (named) contexts;
               restore-over-rebuild, demote to the pool
  transfer.py  the FetchSource ladder (PEER/POOL/DISK/FS/BUILD), donor-
               fanout + bandwidth admission, measured-transfer calibration
  scheduler.py context-aware placement (DEVICE > HOST_RAM > LOCAL_DISK >
               cold ladder, multi-context, contextless, priority hints,
               profile-aware ranking), FetchSource bootstrap decisions
               (fetch_log — identical live and simulated), requeue-on-
               preemption, stragglers
  factory.py   reactive opportunistic pool reconciliation (WorkerFactory)
               + ElasticRunner driving a live manager from capacity traces
  manager.py   live concurrent runtime (worker actor threads + mailboxes,
               real JAX execution, physical preemption demotion,
               donor->receiver peer context transfer) + Future; with
               ``listen()`` workers may be PROCESSES on other nodes
               (RemoteWorker proxies translating the same mailbox
               vocabulary into transport frames)
  transport.py length-prefixed socket frames with per-connection IO
               threads, heartbeats, and two-layer loss detection (EOF +
               declared-lost) feeding the normal preemption path
  wire.py      versioned wire format for snapshots/templates: arrays via
               checkpoint/io's chunked-sha256 path, executables as
               AOTRecipes (receivers compile-cache-hit, never recompile)
  backend.py   ExecutionBackend protocol + SimulatorBackend dry-run
  api.py       PCMClient / ContextHandle (pin, warm_up, demote, residency)
               / FutureBatch (+ legacy @context_app shim, paper Fig. 5)
"""

from repro.core.api import (ContextHandle, FutureBatch, PCMClient,
                            context_app, get_default_client,
                            get_default_manager, load_context, make_recipe,
                            set_default_manager)
from repro.core.backend import (ExecutionBackend, LiveBackend, SimTaskResult,
                                SimulatorBackend)
from repro.core.context import (Context, ContextRecipe, ContextSnapshot,
                                PeerExportError, export_context, materialize,
                                restore_context, snapshot_context)
from repro.core.factory import ElasticRunner, PoolDirective, WorkerFactory
from repro.core.library import (Library, current_context,
                                load_variable_from_context)
from repro.core.manager import Future, PCMManager
from repro.core.scheduler import (Action, Completion, ContextAwareScheduler,
                                  FetchDecision, Task, WorkerPhase)
from repro.core.store import (ContextMode, ContextStore, SnapshotPool, Tier,
                              TierFullError)
from repro.core.transfer import FetchSource, TransferPlan, TransferPlanner
from repro.core.transport import (Connection, Listener, Router,
                                  TransportError)
from repro.core.wire import (WireError, decode_snapshot, decode_template,
                             encode_snapshot, encode_template)

__all__ = [
    "ContextHandle", "FutureBatch", "PCMClient", "context_app",
    "get_default_client", "get_default_manager", "load_context",
    "make_recipe", "set_default_manager", "ExecutionBackend", "LiveBackend",
    "SimTaskResult", "SimulatorBackend", "Context", "ContextRecipe",
    "ContextSnapshot", "PeerExportError", "export_context", "materialize",
    "restore_context", "snapshot_context",
    "ElasticRunner", "PoolDirective", "WorkerFactory",
    "Library", "current_context",
    "load_variable_from_context", "Future", "PCMManager", "Action",
    "Completion", "ContextAwareScheduler", "FetchDecision", "Task",
    "WorkerPhase",
    "ContextMode", "ContextStore", "SnapshotPool", "Tier", "TierFullError",
    "FetchSource", "TransferPlan", "TransferPlanner",
    "Connection", "Listener", "Router", "TransportError",
    "WireError", "decode_snapshot", "decode_template", "encode_snapshot",
    "encode_template",
]
