"""Pervasive Context Management — the paper's primary contribution.

The user entry point is the **PCMClient session API** (api.py): declare
contexts as first-class handles (``client.context`` -> pin / release /
warm_up / residency), attach one or several named contexts to tasks
(``@client.task(contexts={...})``), and submit work as Futures
(``client.submit``) or FutureBatches (``client.map`` ->
``as_completed()`` / ``gather()``, per-future timeouts and callbacks,
priority hints). The client drives a pluggable **ExecutionBackend**
(backend.py): ``PCMManager`` runs tasks live (real JAX inference);
``SimulatorBackend`` dry-runs the identical application against the
discrete-event cluster model — swap one constructor argument to go from
serving to paper-figure simulation.

Module map:
  context.py   ContextRecipe / Context (first-class LLM contexts)
  store.py     tiered per-worker residency + pinning (agnostic/partial/full)
  library.py   persistent executor holding materialized (named) contexts
  transfer.py  shared-FS vs peer-to-peer bootstrap planning
  scheduler.py context-aware placement (multi-context, contextless,
               priority hints), requeue-on-preemption, stragglers
  factory.py   reactive opportunistic pool reconciliation
  manager.py   live in-process runtime (real JAX execution) + Future
  backend.py   ExecutionBackend protocol + SimulatorBackend dry-run
  api.py       PCMClient / ContextHandle / FutureBatch (+ legacy
               @context_app shim, paper Fig. 5)
"""

from repro.core.api import (ContextHandle, FutureBatch, PCMClient,
                            context_app, get_default_client,
                            get_default_manager, load_context, make_recipe,
                            set_default_manager)
from repro.core.backend import (ExecutionBackend, LiveBackend, SimTaskResult,
                                SimulatorBackend)
from repro.core.context import Context, ContextRecipe, materialize
from repro.core.library import (Library, current_context,
                                load_variable_from_context)
from repro.core.manager import Future, PCMManager
from repro.core.scheduler import (Action, Completion, ContextAwareScheduler,
                                  Task, WorkerPhase)
from repro.core.store import ContextMode, ContextStore, Tier
from repro.core.transfer import TransferPlan, TransferPlanner

__all__ = [
    "ContextHandle", "FutureBatch", "PCMClient", "context_app",
    "get_default_client", "get_default_manager", "load_context",
    "make_recipe", "set_default_manager", "ExecutionBackend", "LiveBackend",
    "SimTaskResult", "SimulatorBackend", "Context", "ContextRecipe",
    "materialize", "Library", "current_context",
    "load_variable_from_context", "Future", "PCMManager", "Action",
    "Completion", "ContextAwareScheduler", "Task", "WorkerPhase",
    "ContextMode", "ContextStore", "Tier", "TransferPlan",
    "TransferPlanner",
]
