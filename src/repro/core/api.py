"""User-facing PCM API — the paper's Fig. 5 transformation, JAX-flavored.

    from repro.core.api import context_app, load_context, set_default_manager

    def load_model(arch):                       # runs once per worker
        cfg = get_reduced_config(arch)
        model = build_model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        engine = InferenceEngine(model, params, ...)
        return {"engine": engine}

    @context_app(context=(load_model, ("smollm2-1.7b",)))
    def infer_model(claims):                    # runs per task, reuses ctx
        engine = load_context("engine")
        return engine.generate(claims, max_new_tokens=4)

    verdicts = infer_model(claims).result()
"""

from __future__ import annotations

import functools
from typing import Any, Callable, Optional, Tuple

from repro.core.context import ContextRecipe
from repro.core.library import load_variable_from_context
from repro.core.manager import Future, PCMManager
from repro.core.store import ContextMode

_default_manager: Optional[PCMManager] = None


def set_default_manager(manager: PCMManager):
    global _default_manager
    _default_manager = manager


def get_default_manager() -> PCMManager:
    global _default_manager
    if _default_manager is None:
        _default_manager = PCMManager(mode=ContextMode.FULL, n_workers=1)
    return _default_manager


def load_context(name: str) -> Any:
    """Inside a context_app body: fetch a variable from the held context."""
    return load_variable_from_context(name)


def make_recipe(name: str, builder: Callable, args: Tuple = (),
                **footprints) -> ContextRecipe:
    return ContextRecipe(name=name, **footprints).with_builder(builder,
                                                               *args)


def context_app(context: Optional[Tuple] = None, n_items: int = 1,
                manager: Optional[PCMManager] = None,
                recipe: Optional[ContextRecipe] = None):
    """Decorator: invoking the function submits a PCM task and returns a
    Future. ``context=(builder, args)`` mirrors the paper's parsl_spec."""

    def deco(fn: Callable):
        if recipe is not None:
            task_recipe = recipe
        elif context is not None:
            builder, args = context[0], tuple(context[1]) if len(
                context) > 1 else ()
            task_recipe = make_recipe(f"{fn.__name__}.ctx", builder, args)
        else:
            task_recipe = None

        @functools.wraps(fn)
        def wrapper(*args, **kwargs) -> Future:
            mgr = manager or get_default_manager()
            return mgr.submit(fn, args, kwargs, recipe=task_recipe,
                              n_items=n_items)

        wrapper.recipe = task_recipe
        wrapper.fn = fn
        return wrapper

    return deco
