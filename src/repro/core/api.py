"""PCMClient — the first-class Pervasive Context Management session API.

The paper's Fig. 5 transformation, grown into a session: contexts are
handles you can pin, warm up and introspect; tasks may hold several named
contexts; submission returns Futures (with timeouts and callbacks) or
FutureBatches (``client.map``); and the whole application runs unchanged
against the LIVE runtime or the discrete-event SIMULATOR by swapping the
backend constructor argument.

    from repro.core import PCMClient, SimulatorBackend, load_context

    client = PCMClient(n_workers=2)                  # live JAX backend
    # client = PCMClient(backend=SimulatorBackend(n_workers=20))  # dry-run

    verifier = client.context(load_model, "smollm2-1.7b")   # ContextHandle
    verifier.warm_up()                               # build off-path
    verifier.pin()                                   # survive mode eviction

    @client.task(context=verifier)
    def infer_model(claims):                         # runs per task
        engine = load_context("engine")
        return engine.generate(claims, max_new_tokens=4)

    batch = client.map(infer_model.fn, claim_batches,
                       context=verifier, n_items=16)
    for fut in batch.as_completed():
        consume(fut.result(timeout=60))
    results = batch.gather()

Multi-context tasks name their contexts and resolve variables with
qualified ``load_context("name.var")``:

    @client.task(contexts={"verify": verifier, "rank": ranker})
    def pipeline(claims):
        v = load_context("verify.engine")
        r = load_context("rank.engine")
        ...

Migration from the PR-0 decorator API: ``@context_app(...)`` /
``load_context`` / ``make_recipe`` / ``set_default_manager`` still work
(kept below as thin shims over a default PCMClient) — new code should
construct a PCMClient and use ``client.context`` + ``@client.task``.
"""

from __future__ import annotations

import functools
import threading
import time
from typing import (Any, Callable, Dict, Iterable, Iterator, List, Mapping,
                    Optional, Sequence, Tuple, Union)

from repro.core.context import ContextRecipe
from repro.core.library import load_variable_from_context
from repro.core.manager import Future, PCMManager
from repro.core.store import ContextMode, Tier


def load_context(name: str) -> Any:
    """Inside a PCM task body: fetch a variable from the held context(s).

    ``"var"`` searches the installed contexts; ``"ctxname.var"`` reads from
    one named context of a multi-context task."""
    return load_variable_from_context(name)


def make_recipe(name: str, builder: Callable, args: Tuple = (),
                **footprints) -> ContextRecipe:
    return ContextRecipe(name=name, **footprints).with_builder(builder,
                                                               *args)


# ---------------------------------------------------------------- handles --
class ContextHandle:
    """First-class reference to one context recipe within a client session.

    Wraps the recipe with residency operations on the session's backend:
    ``warm_up`` materializes off the task critical path, ``pin``/``release``
    exempt it from (or return it to) mode-driven eviction, ``residency``
    reports the highest tier each worker holds it at. Usable as a context
    manager (``with handle: ...`` pins for the block)."""

    def __init__(self, client: "PCMClient", recipe: ContextRecipe):
        self._client = client
        self.recipe = recipe
        self._pin_depth = 0

    @property
    def pinned(self) -> bool:
        return self._pin_depth > 0

    @property
    def name(self) -> str:
        return self.recipe.name

    @property
    def key(self) -> str:
        return self.recipe.key()

    def warm_up(self, worker_ids: Optional[List[str]] = None) -> List[str]:
        """Materialize the context on the given (default all) workers now.
        Returns the worker ids warmed."""
        return self._client.backend.warm_up(self.recipe,
                                            worker_ids=worker_ids)

    def demote(self, tier: Tier = Tier.HOST_RAM,
               worker_ids: Optional[List[str]] = None) -> List[str]:
        """Physically move the context off the device: DEVICE -> HOST_RAM
        snapshot (params + engine state via ``jax.device_get``), spilled on
        to LOCAL_DISK with ``tier=Tier.LOCAL_DISK``. The next task that
        needs it RESTORES at transfer cost — zero builder calls, zero
        compiles, bit-identical state. Returns the workers that held it."""
        return self._client.backend.demote_context(self.recipe, tier=tier,
                                                   worker_ids=worker_ids)

    def snapshot_tier(self) -> Optional[Tier]:
        """Tier of the demoted snapshot in the node pool (live backend),
        or None when no demoted copy exists."""
        getter = getattr(self._client.backend, "snapshot_tier", None)
        return None if getter is None else getter(self.recipe)

    def pin(self) -> "ContextHandle":
        """Refcounted: nested pins (e.g. a with-block inside a standing
        pin) only release the backend pin when the count reaches zero."""
        self._pin_depth += 1
        if self._pin_depth == 1:
            self._client.backend.pin_context(self.recipe)
        return self

    def release(self):
        if self._pin_depth == 0:
            return
        self._pin_depth -= 1
        if self._pin_depth == 0:
            self._client.backend.release_context(self.recipe)

    def residency(self) -> Dict[str, Tier]:
        """worker id -> highest tier currently holding this context."""
        return self._client.backend.residency(self.recipe)

    def fetch_history(self) -> List:
        """The FetchSource-ladder decisions the scheduler made for this
        context so far: ``FetchDecision(worker_id, key, source, donor, t)``
        records, in decision order. PEER entries name the donor worker the
        bootstrap was served from. Identical vocabulary on the live and
        simulator backends."""
        return self._client.backend.fetch_history(self.recipe)

    def resident_workers(self, tier: Tier = Tier.DEVICE) -> List[str]:
        return [wid for wid, t in self.residency().items() if t >= tier]

    def __enter__(self) -> "ContextHandle":
        return self.pin()

    def __exit__(self, *exc):
        self.release()
        return False

    def __repr__(self):
        return (f"ContextHandle({self.recipe.name!r}, key={self.key}, "
                f"pinned={self.pinned})")


ContextLike = Union[ContextHandle, ContextRecipe]


def _as_recipe(ctx: ContextLike) -> ContextRecipe:
    return ctx.recipe if isinstance(ctx, ContextHandle) else ctx


# ----------------------------------------------------------------- batches --
class FutureBatch:
    """An ordered collection of Futures from one ``client.map`` call.

    ``gather()`` returns results in submission order; ``as_completed()``
    yields futures in completion order while driving the backend; iteration
    walks the futures in submission order."""

    def __init__(self, futures: Sequence[Future], backend,
                 timeout: Optional[float] = None):
        self._futures: List[Future] = list(futures)
        self._backend = backend
        self._timeout = timeout
        self._completed: List[Future] = []     # completion order
        self._cond = threading.Condition()
        for f in self._futures:
            f.add_done_callback(self._on_done)

    def _on_done(self, fut: Future):
        with self._cond:
            self._completed.append(fut)
            self._cond.notify_all()

    def __len__(self) -> int:
        return len(self._futures)

    def __iter__(self) -> Iterator[Future]:
        return iter(self._futures)

    def __getitem__(self, i) -> Future:
        return self._futures[i]

    @property
    def done(self) -> bool:
        return all(f.done for f in self._futures)

    @property
    def done_count(self) -> int:
        return len(self._completed)

    def add_done_callback(self, cb: Callable[[Future], None]):
        """Attach ``cb`` to every future in the batch."""
        for f in self._futures:
            f.add_done_callback(cb)

    def gather(self, timeout: Optional[float] = None,
               return_exceptions: bool = False) -> List[Any]:
        """Resolve every future; results in submission order. ``timeout``
        bounds the WHOLE batch (defaults to the batch's timeout)."""
        timeout = self._timeout if timeout is None else timeout
        deadline = None if timeout is None else time.monotonic() + timeout
        out: List[Any] = []
        for f in self._futures:
            remaining = (None if deadline is None
                         else max(0.0, deadline - time.monotonic()))
            try:
                out.append(f.result(timeout=remaining))
            except BaseException as e:
                # only capture errors raised BY the task; a batch deadline
                # or lost task (future still unresolved) always propagates
                if not return_exceptions or not f.done:
                    raise
                out.append(e)
        return out

    def as_completed(self, timeout: Optional[float] = None
                     ) -> Iterator[Future]:
        """Yield futures as they complete — ALWAYS in true completion
        order, promptly. ``timeout`` is a rolling per-future deadline: it
        bounds the wait since the LAST yielded completion (reset on every
        yield), not the whole batch — so one slow future raises after
        ``timeout`` stalled seconds without ever delaying or suppressing
        faster completions that keep arriving. On a concurrent backend
        this waits on a condition variable (worker threads progress on
        their own); on the single-threaded simulator it drives the event
        loop stepwise."""
        timeout = self._timeout if timeout is None else timeout
        deadline = None if timeout is None else time.monotonic() + timeout
        concurrent = getattr(self._backend, "concurrent", False)
        yielded = 0
        while yielded < len(self._futures):
            if yielded < len(self._completed):
                yield self._completed[yielded]
                yielded += 1
                # progress resets the rolling deadline: the timeout bounds
                # the gap to the NEXT completion, so an eventually-slow
                # future never blocks the prompt ones from being yielded
                if timeout is not None:
                    deadline = time.monotonic() + timeout
                continue
            if deadline is not None and time.monotonic() >= deadline:
                raise TimeoutError(
                    f"{len(self._futures) - yielded} of "
                    f"{len(self._futures)} futures incomplete after "
                    f"{timeout:.3f}s without progress")
            if concurrent:
                # completions notify immediately; the 0.1s slice is only a
                # heartbeat for the stall checks below
                with self._cond:
                    if len(self._completed) <= yielded:
                        self._cond.wait(0.1)
                if len(self._completed) <= yielded and \
                        self._backend.outstanding == 0:
                    raise RuntimeError(
                        f"{len(self._futures) - yielded} futures lost: "
                        "backend idle with tasks unresolved")
                if deadline is None and \
                        not getattr(self._backend, "workers", True):
                    # no live workers and no deadline: nothing can resolve
                    raise RuntimeError(
                        "backend stalled (no live workers) with "
                        f"{self._backend.outstanding} tasks outstanding")
                continue
            if not self._backend.step():
                if self._backend.outstanding == 0:
                    raise RuntimeError(
                        f"{len(self._futures) - yielded} futures lost: "
                        "backend idle with tasks unresolved")
                if deadline is None:
                    # single-threaded runtime: a stall with work
                    # outstanding cannot resolve itself
                    raise RuntimeError(
                        "backend stalled (no runnable workers?) with "
                        f"{self._backend.outstanding} tasks outstanding")
                time.sleep(0.0001)


# ------------------------------------------------------------------ client --
class PCMClient:
    """A Pervasive-Context-Management session over an ExecutionBackend.

    ``backend`` defaults to a live :class:`PCMManager`; pass a
    :class:`repro.core.backend.SimulatorBackend` to dry-run the identical
    application against modeled cluster time."""

    def __init__(self, backend=None, *, mode: ContextMode = ContextMode.FULL,
                 n_workers: int = 2):
        self.backend = backend if backend is not None else PCMManager(
            mode=mode, n_workers=n_workers)
        self._handles: Dict[str, ContextHandle] = {}
        self._frontdoor = None

    # ---------------------------------------------------------- contexts --
    def context(self, builder_or_recipe: Union[Callable, ContextRecipe],
                *builder_args, name: Optional[str] = None,
                **footprints) -> ContextHandle:
        """Declare a context and get its handle. Accepts a prebuilt
        ContextRecipe, or a builder callable (+ args) from which a recipe
        is made; ``footprints`` forward to ContextRecipe (artifact_bytes,
        device_bytes, ...). Handles are cached per recipe key."""
        if isinstance(builder_or_recipe, ContextRecipe):
            recipe = builder_or_recipe
        else:
            builder = builder_or_recipe
            recipe = ContextRecipe(
                name=name or f"{builder.__name__}.ctx",
                **footprints).with_builder(builder, *builder_args)
        handle = self._handles.get(recipe.key())
        if handle is None:
            handle = ContextHandle(self, recipe)
            self._handles[recipe.key()] = handle
        return handle

    def _named_recipes(self, context: Optional[ContextLike],
                       contexts: Optional[Mapping[str, ContextLike]]
                       ) -> Dict[str, ContextRecipe]:
        if context is not None and contexts is not None:
            raise TypeError("pass either context= or contexts=, not both")
        if contexts is not None:
            return {cname: _as_recipe(c) for cname, c in contexts.items()}
        if context is not None:
            recipe = _as_recipe(context)
            return {recipe.name: recipe}
        return {}

    # -------------------------------------------------------- submission --
    def task(self, context: Optional[ContextLike] = None,
             contexts: Optional[Mapping[str, ContextLike]] = None,
             n_items: int = 1, priority: int = 0):
        """Decorator: invoking the function submits a PCM task and returns
        a Future. ``contexts={"name": handle, ...}`` gives the task several
        named contexts; the body reads them with
        ``load_context("name.var")``."""
        named = self._named_recipes(context, contexts)

        def deco(fn: Callable):
            @functools.wraps(fn)
            def wrapper(*args, **kwargs) -> Future:
                return self.backend.submit(fn, args, kwargs, recipes=named,
                                           n_items=n_items,
                                           priority=priority)

            wrapper.fn = fn
            wrapper.contexts = named
            wrapper.recipe = next(iter(named.values()), None)
            return wrapper

        return deco

    def submit(self, fn: Callable, *args,
               context: Optional[ContextLike] = None,
               contexts: Optional[Mapping[str, ContextLike]] = None,
               n_items: int = 1, priority: int = 0, **kwargs) -> Future:
        """Submit one call of ``fn(*args, **kwargs)`` as a PCM task."""
        named = self._named_recipes(context, contexts)
        return self.backend.submit(fn, args, kwargs, recipes=named,
                                   n_items=n_items, priority=priority)

    def map(self, fn: Callable, items: Iterable, *,
            batch_size: Optional[int] = None,
            context: Optional[ContextLike] = None,
            contexts: Optional[Mapping[str, ContextLike]] = None,
            priority: int = 0, timeout: Optional[float] = None,
            on_done: Optional[Callable[[Future], None]] = None
            ) -> FutureBatch:
        """Bulk submission. Without ``batch_size``, one task per item
        (``fn(item)``); with it, one task per chunk (``fn(list_of_items)``,
        ``n_items=len(chunk)``). ``timeout`` becomes the batch default;
        ``on_done`` runs per future as it resolves. ``priority>0`` is a
        front-of-queue hint honored by the ContextAwareScheduler."""
        named = self._named_recipes(context, contexts)
        seq = list(items)
        if batch_size is None:
            calls = [((item,), 1) for item in seq]
        else:
            if batch_size <= 0:
                raise ValueError("batch_size must be positive")
            calls = [((seq[i:i + batch_size],), len(seq[i:i + batch_size]))
                     for i in range(0, len(seq), batch_size)]
        futures = []
        for call_args, n in calls:
            fut = self.backend.submit(fn, call_args, {}, recipes=named,
                                      n_items=n, priority=priority)
            if on_done is not None:
                fut.add_done_callback(on_done)
            futures.append(fut)
        return FutureBatch(futures, self.backend, timeout=timeout)

    # ------------------------------------------------- streaming sessions --
    def frontdoor(self, **kwargs) -> "Any":
        """The client's streaming front door (admission, per-tenant
        fairness, SLO routing — see ``repro.serving.frontdoor``), created
        on first use. Configuration kwargs (``quotas``, ``lanes``,
        ``engine_var``, ...) are accepted only on the creating call."""
        if self._frontdoor is None:
            from repro.serving.frontdoor import FrontDoor
            self._frontdoor = FrontDoor(self.backend, **kwargs)
        elif kwargs:
            raise ValueError("front door already configured for this "
                             "client — pass kwargs on the first call only")
        return self._frontdoor

    def session(self, context: ContextLike, *, tenant: str = "default",
                slo=None, session_id: Optional[str] = None,
                prefix_key: Optional[str] = None):
        """Open a streaming session against ``context`` (whose built value
        must expose an InferenceEngine under the front door's
        ``engine_var``, default ``"engine"``). Works on the live AND
        simulator backends; ``session.submit(prompt)`` returns a
        TokenStream or raises ShedError on admission backpressure.
        ``prefix_key`` names the session's shared prompt template so the
        router colocates template-mates on one lane (prefix-cache hits)."""
        from repro.serving.session import SLOClass
        return self.frontdoor().open_session(
            context, tenant=tenant, slo=slo or SLOClass.BATCH,
            session_id=session_id, prefix_key=prefix_key)

    def stream(self, prompt, *, context: ContextLike,
               tenant: str = "default", slo=None,
               max_new_tokens: int = 32, temperature: float = 0.0,
               stop_tokens: Tuple[int, ...] = (1,)):
        """One-shot streaming: open an ephemeral session, submit one turn,
        return its TokenStream (iterate it for tokens as they decode)."""
        sess = self.session(context, tenant=tenant, slo=slo)
        try:
            return sess.submit(prompt, max_new_tokens=max_new_tokens,
                               temperature=temperature,
                               stop_tokens=stop_tokens)
        finally:
            sess.close()

    # ----------------------------------------------------------- session --
    def drain(self) -> int:
        """Run the backend until no actions/events are pending."""
        return self.backend.run_until_idle()

    def shutdown(self):
        """Stop the backend's worker threads (live backend; no-op on the
        simulator)."""
        stop = getattr(self.backend, "shutdown", None)
        if stop is not None:
            stop()

    def stats(self) -> Dict:
        return self.backend.stats()

    @property
    def workers(self) -> List[str]:
        return list(self.backend.scheduler.workers)


# --------------------------------------------------- backward-compat shim --
_default_client: Optional[PCMClient] = None


def set_default_manager(manager: PCMManager):
    """Legacy: point the module-level decorator API at a live manager."""
    global _default_client
    _default_client = PCMClient(backend=manager)


def get_default_manager() -> PCMManager:
    return get_default_client().backend


def get_default_client() -> PCMClient:
    global _default_client
    if _default_client is None:
        _default_client = PCMClient(mode=ContextMode.FULL, n_workers=1)
    return _default_client


def context_app(context: Optional[Tuple] = None, n_items: int = 1,
                manager: Optional[PCMManager] = None,
                recipe: Optional[ContextRecipe] = None):
    """Legacy decorator (paper Fig. 5): invoking the function submits a PCM
    task and returns a Future. ``context=(builder, args)`` mirrors the
    paper's parsl_spec. New code: ``PCMClient`` + ``@client.task``."""

    def deco(fn: Callable):
        if recipe is not None:
            task_recipe = recipe
        elif context is not None:
            builder, args = context[0], tuple(context[1]) if len(
                context) > 1 else ()
            task_recipe = make_recipe(f"{fn.__name__}.ctx", builder, args)
        else:
            task_recipe = None

        @functools.wraps(fn)
        def wrapper(*args, **kwargs) -> Future:
            backend = manager if manager is not None \
                else get_default_client().backend
            return backend.submit(fn, args, kwargs, recipe=task_recipe,
                                  n_items=n_items)

        wrapper.recipe = task_recipe
        wrapper.fn = fn
        return wrapper

    return deco
