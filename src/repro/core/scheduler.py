"""Context-aware scheduler — the manager-side half of Pervasive Context
Management.

Pure policy, no clock of its own: callers (the live PCMManager or the
discrete-event cluster simulator) feed it events
(``on_worker_join/leave``, ``on_fetch_done``, ``on_task_done``, ...) and it
returns Actions (StartFetch / StartTask / Requeue). That split lets the
SAME scheduling code run the real runtime and the paper-figure simulations.

Policy highlights (paper §3 + production extensions):
  * placement prefers idle workers whose store already holds the task's
    context at the mode's persist tier (warm-context affinity); candidates
    at the same residency rung are ranked by their DeviceProfile (fastest
    compute for warm/cold starts, fastest PCIe for snapshot restores);
  * cold workers bootstrap down the **FetchSource ladder**
    (PEER / POOL / DISK / FS / BUILD, see ``repro.core.transfer``) by
    PREDICTED SECONDS, not fixed priority: every feasible rung is scored
    with the TransferPlanner's EWMA-calibrated bandwidths (donor fanout
    shares, shared-FS contention, the worker's own PCIe link for snapshot
    promotions, a modeled build cost) and the cheapest wins — a donor that
    measured slow genuinely loses to a local NVMe restore; the canonical
    PEER > POOL > DISK > FS > BUILD order is the deterministic tie-break.
    In full-context mode a queued task whose only idle candidates are cold
    is held while its context is bootstrapped (fetch first, start warm)
    instead of cold-building on the task path; with ``donor_wait`` the
    scheduler queues behind saturated donors — but only when an in-flight
    fetch whose completion can actually unblock THIS key exists and the
    predicted wait + transfer beats the best alternative rung. Every
    ladder decision is recorded in ``fetch_log`` (including commit-time
    degrades from the rung a dry placement decision promised) — the live
    runtime and the discrete-event simulator produce comparable decision
    sequences from the same policy;
  * preempted tasks are requeued at the FRONT (they have already waited);
  * straggler mitigation: optionally duplicate the slowest running task to
    a warm idle worker when it exceeds ``straggler_factor`` x the median
    completed duration; first result wins, the loser is cancelled.
"""

from __future__ import annotations

import collections
import enum
import itertools
import statistics
from dataclasses import dataclass, field
from typing import (Callable, Deque, Dict, List, Optional, Set, Tuple)

from repro.core.context import ContextRecipe
from repro.core.store import ContextMode, ContextStore, Tier, TierFullError
from repro.core.transfer import (GBPS, FetchSource, TransferPlan,
                                 TransferPlanner)


# ------------------------------------------------------------------ types --
@dataclass
class Task:
    """One unit of work. ``recipes`` lists EVERY context the task needs
    (multi-context tasks hold several); an empty tuple means a contextless
    task, which the scheduler treats as always-warm. ``recipe`` remains the
    single-context shorthand and aliases ``recipes[0]``."""

    task_id: str
    recipe: Optional[ContextRecipe] = None
    recipes: Tuple[ContextRecipe, ...] = ()
    context_names: Tuple[str, ...] = () # names aligned with ``recipes``
    n_items: int = 1                    # inferences in this task
    payload: object = None              # live mode: (fn, args, kwargs)
    attempts: int = 0
    submitted_at: float = 0.0
    duplicates_of: Optional[str] = None
    priority: int = 0                   # >0 = front-of-queue hint
    last_worker: str = ""               # most recent placement (diagnostics)

    def __post_init__(self):
        if self.recipe is not None and not self.recipes:
            self.recipes = (self.recipe,)
        elif self.recipes and self.recipe is None:
            self.recipe = self.recipes[0]
        if not self.context_names:
            self.context_names = tuple(r.name for r in self.recipes)

    def keys(self) -> List[str]:
        return [r.key() for r in self.recipes]


class WorkerPhase(enum.Enum):
    IDLE = "idle"
    FETCHING = "fetching"
    BUSY = "busy"


@dataclass
class WorkerInfo:
    worker_id: str
    profile: object = None              # cluster.devices.DeviceProfile
    store: ContextStore = field(default_factory=ContextStore)
    phase: WorkerPhase = WorkerPhase.IDLE
    current: Optional[str] = None       # running / fetching task id
    fetching_key: Optional[str] = None
    fetching_recipe: Optional[ContextRecipe] = None
    fetching_source: Optional[FetchSource] = None
    fetching_donor: str = ""            # PEER fetch: the serving donor
    fetching_eta: Optional[float] = None  # predicted completion time
    joined_at: float = 0.0
    fetch_blocked: Set[str] = field(default_factory=set)  # admission refused
    # how bytes reach/leave this worker: "memcpy" for an in-process
    # thread, "socket" for a worker living in another OS process — feeds
    # the planner's per-kind calibration namespaces
    transport_kind: str = "memcpy"


@dataclass
class FetchDecision:
    """One FetchSource-ladder decision, recorded in ``fetch_log`` when a
    fetch action is issued. The live runtime and the simulator log through
    the same code path, so their sequences are directly comparable."""

    worker_id: str
    key: str
    source: FetchSource
    donor: str = ""                     # PEER decisions: the chosen donor
    t: float = 0.0
    # commit-time degrade: the rung a dry (commit=False) decision promised
    # when the commit landed on a different one (e.g. the promised donor's
    # fanout filled in between) — None for decisions that held
    degraded_from: Optional[FetchSource] = None


@dataclass
class Action:
    kind: str                           # "fetch" | "start" | "cancel"
    worker_id: str
    task_id: str
    plan: Optional[TransferPlan] = None
    recipe: Optional[ContextRecipe] = None
    recipes: Tuple[ContextRecipe, ...] = ()   # all contexts for a start
    warm: bool = False                  # device-resident before this start
    had_disk: bool = False              # ALL contexts disk-resident
    disk_resident: Tuple[bool, ...] = ()      # per-recipe disk residency
    host_resident: Tuple[bool, ...] = ()      # per-recipe host-RAM residency
    device_resident: Tuple[bool, ...] = ()    # per-recipe HBM residency
    source: Optional[FetchSource] = None      # fetch: ladder rung chosen
    donor: str = ""                           # fetch: PEER donor worker id
    donors: Tuple[str, ...] = ()              # PEER stripe lanes, primary 1st
    eta_seconds: float = 0.0        # fetch: scheduler's committed duration
    # prediction (the pipeline-aware rung model that chose the source) —
    # the dry-run surfaces price PEER fetches with it, so modeled timing
    # cannot drift from the policy's own cost model


@dataclass
class Completion:
    task_id: str
    worker_id: str
    t: float
    n_items: int
    duration: float


# -------------------------------------------------------------- scheduler --
class ContextAwareScheduler:
    def __init__(self, mode: ContextMode = ContextMode.FULL,
                 planner: Optional[TransferPlanner] = None,
                 straggler_factor: float = 0.0,
                 max_attempts: int = 100,
                 p2p: bool = True,
                 donor_wait: bool = False,
                 stripe_width: int = 2,
                 fetch_log_limit: int = 4096):
        self.mode = mode
        self.planner = planner or TransferPlanner()
        self.straggler_factor = straggler_factor
        self.max_attempts = max_attempts
        self.p2p = p2p                  # False: FS-only bootstrap (bench)
        # multi-source striping: a PEER bootstrap may pull disjoint chunk
        # ranges from up to this many free donors concurrently (1 = the
        # monolithic single-donor transfer)
        self.stripe_width = stripe_width
        # donor_wait: when every donor is fanout-saturated, hold the fetch
        # until a slot frees instead of taking a worse rung — the paper's
        # admission-controlled join storm. Cost-bounded: engaged only when
        # an in-flight fetch that can unblock THIS key exists (its
        # completion re-drives dispatch, so a wait can never stall the
        # runtime) AND predicted wait + peer transfer beats the cheapest
        # alternative rung (see _wait_for_donor_beats).
        self.donor_wait = donor_wait
        # node SnapshotPool residency oracle (key -> Tier or None),
        # installed by the backend: the POOL/DISK rungs of the ladder
        self.pool_tier: Optional[Callable[[str], Optional[Tier]]] = None
        # template-prefix placement oracle ((task, worker_id) -> bool),
        # installed by serving layers that know which worker's engine
        # already holds a task's shared prompt prefix in its page-level
        # prefix cache (repro.serving.paged.PrefixCache). A hit outranks
        # every equally-placed candidate — the hitting worker skips the
        # shared prefill entirely, which no DeviceProfile edge buys back
        self.prefix_hit: Optional[Callable[[Task, str], bool]] = None
        # ring buffer: long-lived front-door runs issue fetches forever,
        # so the decision log must not grow without bound
        self.fetch_log: Deque[FetchDecision] = collections.deque(
            maxlen=fetch_log_limit)

        self.queue: Deque[Task] = collections.deque()
        self.tasks: Dict[str, Task] = {}
        self.workers: Dict[str, WorkerInfo] = {}
        self.running: Dict[str, Tuple[str, float]] = {}   # task -> (worker, t0)
        self.completions: List[Completion] = []
        self.done_ids: Set[str] = set()
        self.failed: List[Task] = []
        self._durations: List[float] = []

    # ------------------------------------------------------------- events --
    def submit(self, task: Task, t: float = 0.0) -> List[Action]:
        task.submitted_at = t
        self.tasks[task.task_id] = task
        self._enqueue(task)
        return self.dispatch(t)

    def _enqueue(self, task: Task):
        """FIFO, except priority>0 tasks slot in ahead of lower-priority
        work (behind earlier tasks of equal-or-higher priority)."""
        if task.priority <= 0:
            self.queue.append(task)
            return
        idx = 0
        for queued in self.queue:
            if queued.priority >= task.priority:
                idx += 1
            else:
                break
        self.queue.insert(idx, task)

    def on_worker_join(self, worker_id: str, t: float, profile=None,
                       store: Optional[ContextStore] = None,
                       transport_kind: str = "memcpy") -> List[Action]:
        self.workers[worker_id] = WorkerInfo(
            worker_id=worker_id, profile=profile,
            store=store or ContextStore(), joined_at=t,
            transport_kind=transport_kind)
        return self.dispatch(t)

    def on_worker_leave(self, worker_id: str, t: float) -> List[Action]:
        """No-warning preemption: requeue whatever was running/fetching."""
        info = self.workers.pop(worker_id, None)
        if info is None:
            return []
        if info.current is not None:
            task = self.tasks.get(info.current)
            self.running.pop(info.current, None)
            if task and task.task_id not in self.done_ids:
                task.attempts += 1
                if task.attempts >= self.max_attempts:
                    self.failed.append(task)
                elif not self._has_live_duplicate(task):
                    self.queue.appendleft(task)      # preempted work first
        return self.dispatch(t)

    def on_fetch_done(self, worker_id: str, ctx_key: str, t: float
                      ) -> List[Action]:
        info = self.workers.get(worker_id)
        if info is None:
            return []
        info.phase = WorkerPhase.IDLE
        if (info.fetching_recipe is not None
                and info.fetching_recipe.key() == ctx_key):
            try:
                # the fetch materialized the context: record device
                # residency so placement sees the worker as warm and
                # prefetch never re-fires
                info.store.admit_recipe(info.fetching_recipe, Tier.DEVICE,
                                        now=t)
            except TierFullError:
                # admission refused (pinned-full tier): remember the key so
                # prefetch doesn't re-fire forever at this worker. Other
                # ValueErrors are genuine bugs and propagate.
                info.fetch_blocked.add(ctx_key)
        elif info.fetching_recipe is not None:
            # fetch FAILED (builder raised / transfer aborted): block the
            # key at this worker so the next dispatch cold-starts instead
            # of re-fetching forever
            info.fetch_blocked.add(info.fetching_recipe.key())
        info.fetching_key = None
        info.fetching_recipe = None
        info.fetching_source = None
        info.fetching_donor = ""
        info.fetching_eta = None
        info.current = None
        return self.dispatch(t)

    def on_task_done(self, worker_id: str, task_id: str, t: float
                     ) -> List[Action]:
        info = self.workers.get(worker_id)
        task = self.tasks.get(task_id)
        entry = self.running.pop(task_id, None)
        if info is not None:
            info.phase = WorkerPhase.IDLE
            info.current = None
            info.fetch_blocked.clear()   # capacity may have changed
            if self.mode == ContextMode.AGNOSTIC:
                info.store.clear()
            elif self.mode == ContextMode.PARTIAL and task is not None:
                for key in task.keys():
                    info.store.drop(key, down_to=Tier.LOCAL_DISK)
        actions: List[Action] = []
        primary = task.duplicates_of or task_id if task else task_id
        if primary not in self.done_ids:
            self.done_ids.add(primary)
            dur = t - entry[1] if entry else 0.0
            self._durations.append(dur)
            self.completions.append(Completion(
                task_id=primary, worker_id=worker_id, t=t,
                n_items=task.n_items if task else 1, duration=dur))
            actions += self._cancel_other_copies(primary, task_id)
        return actions + self.dispatch(t)

    # ------------------------------------------------- profile-aware rank --
    @staticmethod
    def _compute_rank(w: WorkerInfo):
        """Sort key: fastest accelerator first (warm/cold execution),
        deterministic tie-break on worker id. Workers without a profile
        rank behind profiled ones with nonzero compute."""
        return (-float(getattr(w.profile, "fp16_tflops", 0.0) or 0.0),
                w.worker_id)

    def _placement_rank(self, task: Task):
        """Candidate sort for warm/bootstrap placement. With a
        ``prefix_hit`` oracle installed, a worker holding the task's
        shared prompt prefix sorts ahead of every other candidate at the
        same residency rung; compute rank breaks ties as before. Without
        one this is exactly ``_compute_rank``."""
        if self.prefix_hit is None:
            return self._compute_rank

        def rank(w: WorkerInfo):
            return (0 if self.prefix_hit(task, w.worker_id) else 1,
                    self._compute_rank(w))
        return rank

    @staticmethod
    def _restore_rank(w: WorkerInfo):
        """Sort key for snapshot-promotion placement: restore cost is one
        host->HBM transfer, so the widest PCIe link wins."""
        return (-float(getattr(w.profile, "pcie_gbps", 0.0) or 0.0),
                w.worker_id)

    # ----------------------------------------------------------- dispatch --
    def dispatch(self, t: float) -> List[Action]:
        actions: List[Action] = []
        idle = [w for w in self.workers.values()
                if w.phase == WorkerPhase.IDLE]
        # 1) warm-affinity placement — a worker is warm for a task iff ALL
        #    its contexts are device-resident; contextless tasks (no
        #    recipes) are vacuously warm anywhere. Same-rung candidates are
        #    ranked by DeviceProfile (heterogeneity-aware placement).
        while self.queue and idle:
            task = self.queue[0]
            keys = task.keys()
            warm = sorted((w for w in idle
                           if all(w.store.has(k, Tier.DEVICE)
                                  for k in keys)),
                          key=self._placement_rank(task))
            target = None
            warm_start = False
            if warm:
                target, warm_start = warm[0], True
            else:
                # restore ladder: HOST_RAM (snapshot promotion, one H2D
                # transfer) beats LOCAL_DISK (unspill + load) beats a cold
                # worker (full transfer + build + compile)
                host = sorted((w for w in idle
                               if all(w.store.has(k, Tier.HOST_RAM)
                                      for k in keys)),
                              key=self._restore_rank)
                disk = host or sorted(
                    (w for w in idle
                     if all(w.store.has(k, Tier.LOCAL_DISK)
                            for k in keys)), key=self._restore_rank)
                if disk:
                    target = disk[0]
                else:
                    # every idle candidate is COLD. In full-context mode,
                    # bootstrap the context onto a cold worker down the
                    # FetchSource ladder (fetch first, start warm) when a
                    # cheap source exists, instead of cold-building on the
                    # task critical path.
                    verdict = (self._bootstrap_cold(task, idle, t, actions)
                               if self.mode == ContextMode.FULL and keys
                               else "start")
                    if verdict == "fetch":
                        continue          # idle shrank; task stays queued
                    if verdict == "wait":
                        break             # a completion will re-drive us
                    target = sorted(idle, key=self._compute_rank)[0]
            self.queue.popleft()
            idle.remove(target)
            actions.append(self._start(task, target, t, warm_start))
        # 2) prefetch contexts onto remaining idle workers (full mode only:
        #    it is the mode where warm residency outlives the fetching task).
        #    Demand covers queued AND running recipes: an idle worker warmed
        #    with a running task's context catches its requeue after a
        #    preemption (and hosts straggler duplicates) with zero startup.
        if self.mode == ContextMode.FULL:
            free = list(idle)
            for recipe in self._pending_context_demand():
                if not free:
                    break
                key = recipe.key()
                # offer each demanded recipe to a worker that LACKS it —
                # a worker already warm for it must not consume the demand
                # (and one whose admission was refused stays excluded)
                cands = [w for w in free
                         if not w.store.has(key, Tier.DEVICE)
                         and key not in w.fetch_blocked]
                if not cands:
                    continue
                w = cands[0]
                act = self._fetch(recipe, w, t)
                if act is None:
                    continue              # donor-wait: retry next dispatch
                free.remove(w)
                actions.append(act)
        # 3) straggler duplication
        if self.straggler_factor and not self.queue:
            actions += self._duplicate_stragglers(t)
        return actions

    def _bootstrap_cold(self, task: Task, idle: List[WorkerInfo], t: float,
                        actions: List[Action]) -> str:
        """Try to bootstrap the head task's first missing context onto a
        cold idle worker instead of cold-starting the task. Returns
        "fetch" (fetch issued, worker consumed from ``idle``), "wait"
        (donors saturated, hold the queue for a completing transfer) or
        "start" (no cheap source — cold-start as before)."""
        for w in sorted(idle, key=self._placement_rank(task)):
            # bootstrap the first context THIS candidate is missing
            recipe = next((r for r in task.recipes
                           if not w.store.has(r.key(), Tier.DEVICE)
                           and r.key() not in w.fetch_blocked), None)
            if recipe is None:
                continue
            source, _, wait = self._choose_source(recipe, w, t, commit=False)
            if wait:
                return "wait"
            if source in (FetchSource.PEER, FetchSource.POOL,
                          FetchSource.DISK):
                act = self._fetch(recipe, w, t, expected=source)
                if act is not None:
                    idle.remove(w)
                    actions.append(act)
                    return "fetch"
                # commit found the rung closed AND waiting now predicted
                # cheaper than the alternatives: a key-relevant fetch is
                # in flight, its completion re-drives dispatch
                return "wait"
            break       # cheapest candidate says FS/BUILD: cold-start
        return "start"

    def _start(self, task: Task, w: WorkerInfo, t: float, warm: bool
               ) -> Action:
        # snapshot per-recipe residency BEFORE admitting (admission
        # populates every tier, which would pollute the reading)
        disk_resident = tuple(w.store.has(r.key(), Tier.LOCAL_DISK)
                              for r in task.recipes)
        host_resident = tuple(w.store.has(r.key(), Tier.HOST_RAM)
                              for r in task.recipes)
        device_resident = tuple(w.store.has(r.key(), Tier.DEVICE)
                                for r in task.recipes)
        had_disk = bool(disk_resident) and all(disk_resident)
        w.phase = WorkerPhase.BUSY
        w.current = task.task_id
        task.last_worker = w.worker_id
        self.running[task.task_id] = (w.worker_id, t)
        # residency the task execution will create:
        for recipe in task.recipes:
            try:
                w.store.admit_recipe(recipe, Tier.DEVICE, now=t)
            except TierFullError:
                # pinned entries block admission: the task still runs, but
                # residency is NOT recorded — the store never lies about
                # capacity, and this worker won't be treated as warm for
                # the key it couldn't admit. Only TierFullError is
                # tolerable here; any other ValueError is an admission bug
                # and must propagate.
                pass
            w.store.touch(recipe.key(), now=t)
        return Action(kind="start", worker_id=w.worker_id,
                      task_id=task.task_id, recipe=task.recipe,
                      recipes=task.recipes, warm=warm, had_disk=had_disk,
                      disk_resident=disk_resident,
                      host_resident=host_resident,
                      device_resident=device_resident)

    def _donors_for(self, key: str, exclude: str) -> Set[str]:
        """Workers that can serve the context template peer-to-peer: any
        worker (other than the receiver) holding it DEVICE-resident and
        not itself mid-fetch. DEVICE, not LOCAL_DISK: a worker that
        demoted the context into the node pool still shows lower-tier
        residency but no longer holds a materialized copy to export —
        routing a receiver at it would always degrade to the builder."""
        return {wid for wid, info in self.workers.items()
                if wid != exclude
                and info.phase != WorkerPhase.FETCHING
                and info.store.has(key, Tier.DEVICE)}

    def _pool_claimed(self, key: str) -> bool:
        """True while an in-flight fetch is already promoting this key out
        of the node pool — pool snapshots are single-owner, so a second
        POOL fetch for the same key would race it and cold-build."""
        return any(info.fetching_key == key
                   and info.fetching_source in (FetchSource.POOL,
                                                FetchSource.DISK)
                   for info in self.workers.values())

    # fixed-priority tie-break between rungs predicting equal seconds —
    # the order the uncalibrated defaults produce for a paper-size context
    _LADDER_TIEBREAK = {FetchSource.PEER: 0, FetchSource.POOL: 1,
                        FetchSource.DISK: 2, FetchSource.FS: 3,
                        FetchSource.BUILD: 4}

    @staticmethod
    def _h2d_rate(w: WorkerInfo) -> Optional[float]:
        """The worker's own host->HBM bandwidth (bytes/s) from its
        DeviceProfile; None falls back to the planner's generic link."""
        pcie = float(getattr(w.profile, "pcie_gbps", 0) or 0)
        return pcie * GBPS if pcie > 0 else None

    def _lane_kinds(self, w: WorkerInfo, donors: Set[str]) -> Dict[str, str]:
        """Per-donor transport kind for a transfer INTO ``w``: a lane is a
        socket hop when either endpoint lives in another process, memcpy
        only for thread-to-thread handoff inside this one. Keys the
        planner's per-kind calibration namespaces."""
        if w.transport_kind == "socket":
            return {d: "socket" for d in donors}
        return {d: self.workers[d].transport_kind
                for d in donors if d in self.workers}

    def _rung_costs(self, recipe: ContextRecipe, w: WorkerInfo, t: float
                    ) -> Tuple[List[Tuple[float, int, FetchSource,
                                          Optional[str]]], Set[str]]:
        """Score every FEASIBLE rung for bootstrapping ``recipe`` onto
        ``w`` in predicted seconds (side-effect-free — nothing registers
        with the planner). Returns the rungs sorted cheapest-first (fixed
        ladder order breaks ties) plus the donor set, so callers can tell
        'no donors' from 'donors all fanout-saturated' (donor_wait)."""
        key = recipe.key()
        h2d = self._h2d_rate(w)
        rungs: List[Tuple[float, int, FetchSource, Optional[str]]] = []
        donors: Set[str] = set()
        if self.p2p and self.mode != ContextMode.AGNOSTIC:
            donors = self._donors_for(key, w.worker_id)
        if donors:
            best = self.planner.peer_seconds(recipe.transfer_bytes,
                                             donors, t,
                                             width=self.stripe_width,
                                             kinds=self._lane_kinds(w,
                                                                    donors))
            if best is not None:
                donor, transfer_s = best
                # the receiver restores the shipped template host->HBM;
                # no framework warm-up (its process is already alive) and
                # no compile (AOT executables ride along). Chunk-streamed:
                # the donor's device_get, the wire, and the receiver's
                # device_put pipeline instead of summing
                rungs.append((self.planner.pipeline_seconds(
                    [self.planner.d2h_seconds(recipe.transfer_bytes),
                     transfer_s,
                     self.planner.restore_seconds(
                         recipe.host_bytes, h2d_bytes_per_s=h2d)],
                    recipe.transfer_bytes),
                    self._LADDER_TIEBREAK[FetchSource.PEER],
                    FetchSource.PEER, donor))
        pool_tier = self.pool_tier(key) if self.pool_tier is not None \
            else None
        if pool_tier is not None and not self._pool_claimed(key):
            from_disk = Tier(pool_tier) == Tier.LOCAL_DISK
            src = FetchSource.DISK if from_disk else FetchSource.POOL
            rungs.append((self.planner.restore_seconds(
                recipe.host_bytes, from_disk=from_disk, h2d_bytes_per_s=h2d),
                self._LADDER_TIEBREAK[src], src, None))
        if recipe.transfer_bytes > 0:
            rungs.append((self.planner.cold_seconds(
                recipe.transfer_bytes, recipe.host_bytes, t,
                h2d_bytes_per_s=h2d),
                self._LADDER_TIEBREAK[FetchSource.FS], FetchSource.FS, None))
        rungs.append((self.planner.build_seconds(recipe.transfer_bytes),
                      self._LADDER_TIEBREAK[FetchSource.BUILD],
                      FetchSource.BUILD, None))
        rungs.sort(key=lambda r: (r[0], r[1]))
        return rungs, donors

    def rung_costs(self, recipe: ContextRecipe, worker_id: str, t: float
                   ) -> List[Tuple[FetchSource, float, str]]:
        """Public observability surface of the cost chooser: the feasible
        rungs for bootstrapping ``recipe`` onto ``worker_id`` as
        ``(source, predicted_seconds, donor)`` tuples, cheapest first —
        what ``_choose_source`` would pick and why."""
        rungs, _ = self._rung_costs(recipe, self.workers[worker_id], t)
        return [(src, sec, donor or "") for sec, _, src, donor in rungs]

    def _wait_for_donor_beats(self, key: str, recipe: ContextRecipe,
                              w: WorkerInfo, donors: Set[str], t: float,
                              best_alternative: float) -> bool:
        """donor_wait admission: hold this fetch for a donor slot ONLY if
        (a) an in-flight fetch exists whose completion can actually
        unblock THIS key — a receiver currently drawing from one of its
        donors (frees a fanout slot), or a worker fetching the same key
        (becomes a new donor) — and (b) the predicted wait plus an
        unconstrained peer transfer still beats the best alternative rung.
        Scoping to key-relevant fetches is both correctness (a joiner must
        not queue behind an unrelated transfer that will never free a
        donor for it) and liveness (each unblocker is a scheduler-tracked
        fetch whose completion re-drives dispatch)."""
        etas = [info.fetching_eta for info in self.workers.values()
                if info.phase == WorkerPhase.FETCHING
                and info.fetching_eta is not None
                and (info.fetching_key == key
                     or (info.fetching_donor
                         and info.fetching_donor in donors))]
        if not etas:
            return False
        wait_s = max(0.0, min(etas) - t)
        peer_s = (self.planner.peer_rate_seconds(recipe.transfer_bytes,
                                                 kind=w.transport_kind)
                  + self.planner.restore_seconds(
                      recipe.host_bytes, h2d_bytes_per_s=self._h2d_rate(w)))
        return wait_s + peer_s < best_alternative

    def _choose_source(self, recipe: ContextRecipe, w: WorkerInfo, t: float,
                       commit: bool = True
                       ) -> Tuple[Optional[FetchSource],
                                  Optional[TransferPlan], bool]:
        """Pick the cheapest FetchSource rung (predicted seconds, see
        ``_rung_costs``) for bootstrapping ``recipe`` onto ``w``. Returns
        (source, plan, wait). ``wait=True`` means every donor is fanout-
        saturated and waiting for a slot is predicted cheaper than the
        best alternative rung (donor_wait). With ``commit=False`` nothing
        is registered with the planner — a dry decision for placement;
        re-invoke with ``commit=True`` (via ``_fetch``) to reserve the
        flow. The commit path re-validates with the SAME admission
        predicate and walks the cost order, so a rung that closed between
        dry and commit degrades to the next-cheapest (``_fetch`` logs the
        degrade) instead of silently changing shape."""
        rungs, donors = self._rung_costs(recipe, w, t)
        best_sec, _, best_src, _ = rungs[0]
        peer_feasible = any(r[2] == FetchSource.PEER for r in rungs)
        if (self.donor_wait and donors and not peer_feasible
                and self._wait_for_donor_beats(recipe.key(), recipe, w,
                                               donors, t, best_sec)):
            return None, None, True
        if not commit:
            return best_src, None, False
        for _, _, source, donor in rungs:
            if source == FetchSource.PEER:
                plan = self.planner.peer_plan(recipe.transfer_bytes,
                                              donors, t,
                                              width=self.stripe_width,
                                              kinds=self._lane_kinds(w,
                                                                     donors))
                if plan is None:
                    # defensive only: within one call the scoring and the
                    # commit see the same planner state at the same t, so
                    # a scored-feasible PEER rung always commits — but a
                    # plan-less PEER action would silently run the builder
                    # on the receiver, so degrade rather than ship one
                    continue
                return FetchSource.PEER, plan, False
            if source in (FetchSource.POOL, FetchSource.DISK):
                plan = self.planner.pool_plan(
                    recipe.host_bytes, t,
                    from_disk=source == FetchSource.DISK,
                    h2d_bytes_per_s=self._h2d_rate(w))
                return source, plan, False
            if source == FetchSource.FS:
                return source, self.planner.fs_plan(recipe.transfer_bytes,
                                                    t), False
            return FetchSource.BUILD, None, False
        # unreachable: _rung_costs always appends the BUILD rung, and the
        # loop returns unconditionally when it reaches it

    def _fetch_eta(self, source: FetchSource, plan: Optional[TransferPlan],
                   recipe: ContextRecipe, w: WorkerInfo, t: float) -> float:
        """Predicted completion time of a fetch just issued — the transfer
        plus what the receiver does with it (mirroring the shape of the
        backends' fetch execution): a PEER install restores the shipped
        template host->HBM, POOL/DISK promotions are the plan alone, an FS
        fetch pays the full cold load (warm-up + disk read + host->HBM),
        and BUILD is the chooser's own build-cost model. Feeds
        ``_wait_for_donor_beats`` — a wait estimate, not a contract."""
        h2d = self._h2d_rate(w)
        if source in (FetchSource.POOL, FetchSource.DISK):
            return t + plan.seconds
        if source == FetchSource.PEER:
            # same chunk-pipelined d2h/wire/restore composition as the
            # rung score in _rung_costs — score, wait estimate, and the
            # dry-run surfaces' fetch pricing all read one formula
            return t + self.planner.pipeline_seconds(
                [self.planner.d2h_seconds(recipe.transfer_bytes),
                 plan.seconds,
                 self.planner.restore_seconds(recipe.host_bytes,
                                              h2d_bytes_per_s=h2d)],
                recipe.transfer_bytes)
        if source == FetchSource.FS:
            return t + plan.seconds + self.planner.cold_load_seconds(
                recipe.transfer_bytes, recipe.host_bytes,
                h2d_bytes_per_s=h2d)
        return t + self.planner.build_seconds(recipe.transfer_bytes)

    def _fetch(self, recipe: ContextRecipe, w: WorkerInfo, t: float,
               expected: Optional[FetchSource] = None) -> Optional[Action]:
        """Issue a bootstrap fetch for ``recipe`` on ``w`` at the cheapest
        FetchSource rung; None when the policy decides to wait for a donor
        slot. The decision is appended to ``fetch_log``; when a caller
        passes the rung its dry decision promised (``expected``) and the
        commit lands elsewhere, the decision records the degrade."""
        source, plan, wait = self._choose_source(recipe, w, t, commit=True)
        if wait:
            return None
        donor = plan.source if (plan is not None and plan.p2p) else ""
        self.fetch_log.append(FetchDecision(
            worker_id=w.worker_id, key=recipe.key(), source=source,
            donor=donor, t=t,
            degraded_from=expected if (expected is not None
                                       and expected != source) else None))
        w.phase = WorkerPhase.FETCHING
        w.fetching_key = recipe.key()
        w.fetching_recipe = recipe
        w.fetching_source = source
        w.fetching_donor = donor
        w.fetching_eta = self._fetch_eta(source, plan, recipe, w, t)
        w.current = None
        return Action(kind="fetch", worker_id=w.worker_id, task_id="",
                      plan=plan, recipe=recipe, source=source, donor=donor,
                      donors=plan.stripes if plan is not None else (),
                      eta_seconds=w.fetching_eta - t)

    def record_degrade(self, worker_id: str, key: str, source: FetchSource,
                       t: float, degraded_from: FetchSource,
                       donor: str = ""):
        """Log a runtime degrade the policy could not see at commit time —
        e.g. a striped PEER transfer whose every lane died mid-stream and
        whose receiver fell back down the ladder via its Library. Keeps
        ``fetch_log`` the complete account of where every context
        actually came from."""
        self.fetch_log.append(FetchDecision(
            worker_id=worker_id, key=key, source=source, donor=donor, t=t,
            degraded_from=degraded_from))

    def _pending_context_demand(self) -> List[ContextRecipe]:
        # scan a bounded prefix: queues can hold 100k+ tasks and demand is
        # dominated by the first few distinct recipes anyway
        seen = {}
        for task in itertools.islice(self.queue, 256):
            for recipe in task.recipes:
                seen.setdefault(recipe.key(), recipe)
        for tid in itertools.islice(self.running, 64):
            task = self.tasks.get(tid)
            if task is not None:
                for recipe in task.recipes:
                    seen.setdefault(recipe.key(), recipe)
        return list(seen.values())

    # ---------------------------------------------------------- straggler --
    def _duplicate_stragglers(self, t: float) -> List[Action]:
        if len(self._durations) < 5 or not self.running:
            return []
        med = statistics.median(self._durations)
        if med <= 0:
            return []
        actions = []
        idle_warm = [w for w in self.workers.values()
                     if w.phase == WorkerPhase.IDLE]
        for task_id, (wid, t0) in list(self.running.items()):
            if not idle_warm:
                break
            task = self.tasks.get(task_id)
            if task is None or task.duplicates_of is not None:
                continue
            if self._has_live_duplicate(task, exclude=task_id):
                continue
            if (t - t0) > self.straggler_factor * med:
                keys = task.keys()
                cands = [w for w in idle_warm
                         if all(w.store.has(k, Tier.DEVICE) for k in keys)
                         ] or idle_warm
                w = cands[0]
                idle_warm.remove(w)
                dup = Task(task_id=f"{task_id}~dup{task.attempts}",
                           recipes=task.recipes,
                           context_names=task.context_names,
                           n_items=task.n_items,
                           payload=task.payload, duplicates_of=task_id)
                self.tasks[dup.task_id] = dup
                actions.append(self._start(
                    dup, w, t,
                    all(w.store.has(k, Tier.DEVICE) for k in keys)))
        return actions

    def _has_live_duplicate(self, task: Task, exclude: str = "") -> bool:
        primary = task.duplicates_of or task.task_id
        for tid in self.running:
            if tid == exclude:
                continue
            other = self.tasks.get(tid)
            if other and (other.duplicates_of or other.task_id) == primary:
                return True
        return False

    def _cancel_other_copies(self, primary: str, done_tid: str
                             ) -> List[Action]:
        actions = []
        for tid, (wid, _) in list(self.running.items()):
            other = self.tasks.get(tid)
            if other and tid != done_tid and \
                    (other.duplicates_of or other.task_id) == primary:
                self.running.pop(tid)
                info = self.workers.get(wid)
                if info:
                    info.phase = WorkerPhase.IDLE
                    info.current = None
                actions.append(Action(kind="cancel", worker_id=wid,
                                      task_id=tid))
        # drop queued copies too (only rebuild the deque when needed —
        # O(queue) per completion would be quadratic on 100k-task sweeps)
        if any(tk.duplicates_of is not None for tk in
               itertools.islice(self.queue, 64)) or actions:
            self.queue = collections.deque(
                tk for tk in self.queue
                if (tk.duplicates_of or tk.task_id) != primary)
        return actions

    # ------------------------------------------------------------- status --
    def fetch_history(self, recipe: Optional[ContextRecipe] = None
                      ) -> List[FetchDecision]:
        """The FetchSource-ladder decisions issued so far, optionally
        filtered to one recipe. Backends expose this under their own
        locking."""
        log = list(self.fetch_log)
        if recipe is not None:
            key = recipe.key()
            log = [d for d in log if d.key == key]
        return log

    @property
    def outstanding(self) -> int:
        return len(self.queue) + len(self.running)

    def all_done(self) -> bool:
        live = {tid for tid, tk in self.tasks.items()
                if tk.duplicates_of is None}
        return live.issubset(self.done_ids)
