"""PCMManager — the live (in-process) PCM runtime.

Runs the same ContextAwareScheduler as the cluster simulator, but executes
tasks for real: each logical worker owns a Library whose contexts are
actual JAX objects (weights + jitted executables + KV pools). On this
single-host container the workers time-share the CPU device; on a real
cluster each worker binds a TPU slice and the same code applies.

Live preemption (``preempt_worker``) drops the worker and its device-tier
contexts mid-flight; the scheduler requeues and the task re-runs on a warm
worker — the end-to-end mechanism of the paper, measurable with real
inference (examples/opportunistic_serving.py).

PCMManager implements the ``ExecutionBackend`` protocol
(:mod:`repro.core.backend`): the PCMClient session API drives it
interchangeably with the simulator-backed dry-run backend.
"""

from __future__ import annotations

import itertools
import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Mapping, Optional

from repro.core.context import ContextRecipe
from repro.core.library import Library
from repro.core.scheduler import (Action, ContextAwareScheduler, ContextMode,
                                  Task)
from repro.core.store import ContextStore, Tier
from repro.core.transfer import TransferPlanner


class Future:
    """Handle to one submitted task. Resolved by the backend's event loop;
    ``result(timeout=...)`` drives the backend until the value is ready."""

    def __init__(self, task_id: str, backend):
        self.task_id = task_id
        self._backend = backend
        self._value: Any = None
        self._ready = False
        self.error: Optional[BaseException] = None
        self._callbacks: List[Callable[["Future"], None]] = []

    # ------------------------------------------------------- resolution ----
    def set_result(self, value: Any):
        if self._ready:
            return
        self._value = value
        self._ready = True
        self._fire_callbacks()

    def set_exception(self, error: BaseException):
        if self._ready:
            return
        self.error = error
        self._ready = True
        self._fire_callbacks()

    def _fire_callbacks(self):
        callbacks, self._callbacks = self._callbacks, []
        for cb in callbacks:
            cb(self)

    def add_done_callback(self, cb: Callable[["Future"], None]):
        """Run ``cb(self)`` once the future resolves (immediately if it
        already has)."""
        if self._ready:
            cb(self)
        else:
            self._callbacks.append(cb)

    # --------------------------------------------------------- consumers ---
    def result(self, timeout: Optional[float] = None) -> Any:
        # stepwise, not run_until_idle: the deadline is checked between
        # actions, so a timeout can't be overshot by the whole backlog
        deadline = None if timeout is None else time.monotonic() + timeout
        while not self._ready:
            progressed = self._backend.step()
            if self._ready:
                break
            if not progressed:
                if self._backend.outstanding == 0:
                    raise RuntimeError(self._lost_message())
                if deadline is None:
                    # single-threaded runtime: no event can arrive while we
                    # block here, so a stall with work outstanding is final
                    raise RuntimeError(
                        f"backend stalled with {self._backend.outstanding} "
                        f"task(s) outstanding and no runnable workers "
                        f"while waiting on {self.task_id} — add workers or "
                        "pass result(timeout=...)")
                time.sleep(0.001)   # bounded wait until the deadline
            if deadline is not None and time.monotonic() >= deadline:
                raise TimeoutError(
                    f"task {self.task_id} did not complete within "
                    f"{timeout:.3f}s ({self._backend.outstanding} tasks "
                    "still outstanding)")
        if self.error is not None:
            raise self.error
        return self._value

    def _lost_message(self) -> str:
        task = self._backend.lookup_task(self.task_id)
        if task is None:
            return f"task {self.task_id} lost (unknown to the scheduler)"
        where = task.last_worker or "<never placed>"
        return (f"task {self.task_id} lost after {task.attempts} attempt(s); "
                f"last worker {where} — exceeded max_attempts or the pool "
                "drained with the task unfinished")

    @property
    def done(self) -> bool:
        return self._ready


@dataclass
class LiveWorker:
    worker_id: str
    library: Library
    store: ContextStore


class PCMManager:
    def __init__(self, mode: ContextMode = ContextMode.FULL,
                 n_workers: int = 2,
                 planner: Optional[TransferPlanner] = None):
        self.mode = mode
        self.scheduler = ContextAwareScheduler(mode=mode, planner=planner)
        self.workers: Dict[str, LiveWorker] = {}
        self._futures: Dict[str, Future] = {}
        self._ids = itertools.count()
        self._task_ids = itertools.count()
        self._pinned: set = set()
        self._pending_actions: List[Action] = []
        for _ in range(n_workers):
            self.add_worker()

    # ------------------------------------------------------------- pool ----
    def add_worker(self) -> str:
        wid = f"live{next(self._ids):03d}"
        w = LiveWorker(wid, Library(wid), ContextStore())
        w.store.pinned.update(self._pinned)
        w.library.pinned.update(self._pinned)
        self.workers[wid] = w
        acts = self.scheduler.on_worker_join(wid, time.monotonic(),
                                             store=w.store)
        self._pending_actions.extend(acts)
        return wid

    def preempt_worker(self, worker_id: str):
        """No-warning eviction: device contexts are gone instantly (pins
        don't survive losing the device)."""
        w = self.workers.pop(worker_id, None)
        if w is not None:
            w.library.evict_all(force=True)
        acts = self.scheduler.on_worker_leave(worker_id, time.monotonic())
        self._pending_actions.extend(acts)

    # ------------------------------------------------------------ submit ---
    def submit(self, fn: Callable, args: tuple = (), kwargs: dict = None,
               recipe: Optional[ContextRecipe] = None,
               recipes: Optional[Mapping[str, ContextRecipe]] = None,
               n_items: int = 1, priority: int = 0) -> Future:
        """Submit one task. ``recipe=None`` (and no ``recipes``) is an
        explicitly contextless task — the scheduler treats it as warm on
        every worker. ``recipes`` maps context names to recipes for
        multi-context tasks."""
        named: Dict[str, ContextRecipe] = dict(recipes or {})
        if recipe is not None and not named:
            named = {recipe.name: recipe}
        task_id = f"t{next(self._task_ids):05d}"
        task = Task(task_id=task_id, recipes=tuple(named.values()),
                    context_names=tuple(named.keys()), n_items=n_items,
                    priority=priority, payload=(fn, args, kwargs or {}))
        fut = Future(task_id, self)
        self._futures[task_id] = fut
        acts = self.scheduler.submit(task, time.monotonic())
        self._pending_actions.extend(acts)
        return fut

    # ----------------------------------------------------------- contexts --
    def warm_up(self, recipe: ContextRecipe,
                worker_ids: Optional[List[str]] = None) -> List[str]:
        """Materialize ``recipe`` on the given (default: all) workers now,
        off the task critical path."""
        warmed = []
        for wid in list(worker_ids or self.workers):
            w = self.workers.get(wid)
            if w is None:
                continue
            w.library.ensure(recipe)
            w.store.admit_recipe(recipe, self.mode.persist_tier)
            warmed.append(wid)
        return warmed

    def pin_context(self, recipe: ContextRecipe):
        """Exempt the context from mode-driven eviction on every current
        and future worker."""
        key = recipe.key()
        self._pinned.add(key)
        for w in self.workers.values():
            w.store.pin(key)
            w.library.pin(key)

    def release_context(self, recipe: ContextRecipe):
        key = recipe.key()
        self._pinned.discard(key)
        for w in self.workers.values():
            w.store.unpin(key)
            w.library.unpin(key)

    def residency(self, recipe: ContextRecipe) -> Dict[str, Tier]:
        """Highest tier at which each worker currently holds the context."""
        key = recipe.key()
        return {wid: w.store.highest_tier(key)
                for wid, w in self.workers.items()}

    # --------------------------------------------------------- execution ---
    def step(self) -> bool:
        """Execute one pending scheduler action; False when idle."""
        if not self._pending_actions:
            return False
        self._execute(self._pending_actions.pop(0))
        return True

    def run_until_idle(self) -> int:
        """Drain actions; single-host execution is synchronous per action.
        Returns the number of actions executed."""
        n = 0
        while self.step():
            n += 1
            if n > 100_000:
                raise RuntimeError("scheduler action loop did not converge")
        return n

    def _execute(self, action: Action):
        now = time.monotonic()
        w = self.workers.get(action.worker_id)
        if w is None:
            if action.kind == "start":
                acts = self.scheduler.on_worker_leave(action.worker_id, now)
                self._pending_actions.extend(acts)
            return
        if action.kind == "fetch":
            # live mode: materialize immediately (the build IS the fetch)
            w.library.ensure(action.recipe)
            w.store.admit_recipe(action.recipe, self.mode.persist_tier)
            acts = self.scheduler.on_fetch_done(action.worker_id,
                                                action.recipe.key(), now)
            self._pending_actions.extend(acts)
        elif action.kind == "start":
            task = self.scheduler.tasks[action.task_id]
            fn, args, kwargs = task.payload
            fut = self._futures.get(task.duplicates_of or task.task_id)
            try:
                named = dict(zip(task.context_names, task.recipes))
                value = w.library.invoke(fn, args, kwargs,
                                         recipes=named or None,
                                         task_id=task.task_id)
                if self.mode == ContextMode.AGNOSTIC:
                    w.library.evict_all()
                elif self.mode == ContextMode.PARTIAL:
                    for key in task.keys():
                        w.library.evict(key)
                if fut:
                    fut.set_result(value)
            except BaseException as e:   # report, don't wedge the pool
                if fut:
                    fut.set_exception(e)
            acts = self.scheduler.on_task_done(action.worker_id,
                                               action.task_id,
                                               time.monotonic())
            self._pending_actions.extend(acts)
        elif action.kind == "cancel":
            pass  # synchronous execution never has an in-flight copy

    # ------------------------------------------------------------- status ---
    @property
    def outstanding(self) -> int:
        return self.scheduler.outstanding

    def lookup_task(self, task_id: str) -> Optional[Task]:
        return self.scheduler.tasks.get(task_id)

    # ------------------------------------------------------------- stats ---
    def stats(self) -> Dict:
        cold = warm = 0
        build_s = 0.0
        for w in self.workers.values():
            for rec in w.library.records:
                cold += rec.cold
                warm += not rec.cold
            build_s += w.library.build_seconds_total
        return {"cold_invocations": cold, "warm_invocations": warm,
                "context_build_seconds": build_s,
                "completed": len(self.scheduler.completions)}
