"""PCMManager — the live (in-process) PCM runtime.

Runs the same ContextAwareScheduler as the cluster simulator, but executes
tasks for real: each logical worker owns a Library whose contexts are
actual JAX objects (weights + jitted executables + KV pools). On this
single-host container the workers time-share the CPU device; on a real
cluster each worker binds a TPU slice and the same code applies.

Live preemption (``preempt_worker``) drops the worker and its device-tier
contexts mid-flight; the scheduler requeues and the task re-runs on a warm
worker — the end-to-end mechanism of the paper, measurable with real
inference (examples/opportunistic_serving.py).
"""

from __future__ import annotations

import itertools
import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional

from repro.core.context import ContextRecipe
from repro.core.library import Library
from repro.core.scheduler import (Action, ContextAwareScheduler, ContextMode,
                                  Task)
from repro.core.store import ContextStore, Tier
from repro.core.transfer import TransferPlanner


@dataclass
class Future:
    task_id: str
    _manager: "PCMManager"
    _value: Any = None
    _ready: bool = False
    error: Optional[BaseException] = None

    def result(self) -> Any:
        while not self._ready:
            self._manager.run_until_idle()
            if not self._ready and self._manager.scheduler.outstanding == 0:
                raise RuntimeError(f"task {self.task_id} lost "
                                   "(exceeded max attempts?)")
        if self.error is not None:
            raise self.error
        return self._value

    @property
    def done(self) -> bool:
        return self._ready


@dataclass
class LiveWorker:
    worker_id: str
    library: Library
    store: ContextStore


class PCMManager:
    def __init__(self, mode: ContextMode = ContextMode.FULL,
                 n_workers: int = 2,
                 planner: Optional[TransferPlanner] = None):
        self.mode = mode
        self.scheduler = ContextAwareScheduler(mode=mode, planner=planner)
        self.workers: Dict[str, LiveWorker] = {}
        self._futures: Dict[str, Future] = {}
        self._ids = itertools.count()
        self._pending_actions: List[Action] = []
        for _ in range(n_workers):
            self.add_worker()

    # ------------------------------------------------------------- pool ----
    def add_worker(self) -> str:
        wid = f"live{next(self._ids):03d}"
        w = LiveWorker(wid, Library(wid), ContextStore())
        self.workers[wid] = w
        acts = self.scheduler.on_worker_join(wid, time.monotonic(),
                                             store=w.store)
        self._pending_actions.extend(acts)
        return wid

    def preempt_worker(self, worker_id: str):
        """No-warning eviction: device contexts are gone instantly."""
        w = self.workers.pop(worker_id, None)
        if w is not None:
            w.library.evict_all()
        acts = self.scheduler.on_worker_leave(worker_id, time.monotonic())
        self._pending_actions.extend(acts)

    # ------------------------------------------------------------ submit ---
    def submit(self, fn: Callable, args: tuple = (), kwargs: dict = None,
               recipe: Optional[ContextRecipe] = None,
               n_items: int = 1) -> Future:
        task_id = f"t{len(self.scheduler.tasks):05d}"
        task = Task(task_id=task_id, recipe=recipe or ContextRecipe(
            name="null", artifact_bytes=0, env_bytes=0, host_bytes=0,
            device_bytes=0), n_items=n_items,
            payload=(fn, args, kwargs or {}))
        fut = Future(task_id=task_id, _manager=self)
        self._futures[task_id] = fut
        acts = self.scheduler.submit(task, time.monotonic())
        self._pending_actions.extend(acts)
        return fut

    # --------------------------------------------------------- execution ---
    def run_until_idle(self):
        """Drain actions; single-host execution is synchronous per action."""
        guard = 0
        while self._pending_actions:
            guard += 1
            if guard > 100_000:
                raise RuntimeError("scheduler action loop did not converge")
            action = self._pending_actions.pop(0)
            self._execute(action)

    def _execute(self, action: Action):
        now = time.monotonic()
        w = self.workers.get(action.worker_id)
        if w is None:
            if action.kind == "start":
                acts = self.scheduler.on_worker_leave(action.worker_id, now)
                self._pending_actions.extend(acts)
            return
        if action.kind == "fetch":
            # live mode: materialize immediately (the build IS the fetch)
            w.library.ensure(action.recipe)
            w.store.admit_recipe(action.recipe, self.mode.persist_tier)
            acts = self.scheduler.on_fetch_done(action.worker_id,
                                                action.recipe.key(), now)
            self._pending_actions.extend(acts)
        elif action.kind == "start":
            task = self.scheduler.tasks[action.task_id]
            fn, args, kwargs = task.payload
            fut = self._futures.get(task.duplicates_of or task.task_id)
            try:
                value = w.library.invoke(
                    fn, args, kwargs,
                    recipe=task.recipe if task.recipe.name != "null" else None,
                    task_id=task.task_id)
                if self.mode == ContextMode.AGNOSTIC:
                    w.library.evict_all()
                elif self.mode == ContextMode.PARTIAL:
                    w.library.evict(task.recipe.key())
                if fut and not fut._ready:
                    fut._value = value
                    fut._ready = True
            except BaseException as e:   # report, don't wedge the pool
                if fut and not fut._ready:
                    fut.error = e
                    fut._ready = True
            acts = self.scheduler.on_task_done(action.worker_id,
                                               action.task_id,
                                               time.monotonic())
            self._pending_actions.extend(acts)
        elif action.kind == "cancel":
            pass  # synchronous execution never has an in-flight copy

    # ------------------------------------------------------------- stats ---
    def stats(self) -> Dict:
        cold = warm = 0
        build_s = 0.0
        for w in self.workers.values():
            for rec in w.library.records:
                cold += rec.cold
                warm += not rec.cold
            build_s += w.library.build_seconds_total
        return {"cold_invocations": cold, "warm_invocations": warm,
                "context_build_seconds": build_s,
                "completed": len(self.scheduler.completions)}
