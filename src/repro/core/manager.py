"""PCMManager — the live concurrent (in-process) PCM runtime.

Actor-style execution core. Each logical worker is a **thread with a
mailbox** (:class:`LiveWorker`) that owns its :class:`Library` and
:class:`ContextStore`: builds, invocations, demotions and restores for a
worker all happen on its own thread, serialized by the mailbox. The
manager side — the ContextAwareScheduler, the Future table and the task
clock — lives behind one lock; every runtime event (submit, fetch-done,
task-done, join, leave) enters through that lock, asks the scheduler for
Actions, and routes them to worker mailboxes. Nothing busy-polls:
Futures carry condition variables and resolve the moment a worker reports
completion.

Context tier movement is PHYSICAL here. Preempting a worker
(``preempt_worker``) reclaims its device: the scheduler instantly requeues
its in-flight task (no-warning semantics), and the worker's retirement
demotes every device-resident context into the node
:class:`~repro.core.store.SnapshotPool` — params and engine state pulled
to host RAM via ``jax.device_get``, AOT-executable handles retained, LRU
snapshots spilling to local disk through ``checkpoint/io``. A later
``add_worker`` (or any worker that needs the context) PROMOTES the
snapshot instead of re-running the builder: zero builder calls, zero XLA
compiles, bit-identical decode state — the paper's restore-cost-not-
startup-cost claim, executed for real.

All scheduler event timestamps come from one clock source: ``self.now``
(monotonic seconds since the manager started). The simulator backend uses
its event-loop clock the same way, so durations and completions are
comparable across backends.

PCMManager implements the ``ExecutionBackend`` protocol
(:mod:`repro.core.backend`): the PCMClient session API drives it
interchangeably with the simulator-backed dry-run backend.
"""

from __future__ import annotations

import atexit
import itertools
import pickle
import queue
import sys
import threading
import time
import traceback
import weakref
from typing import Any, Callable, Dict, List, Mapping, Optional, Tuple

import numpy as np

from repro.core import wire as pcm_wire
from repro.core.context import (GB, ContextRecipe, ContextSnapshot,
                                export_context, restore_context,
                                stripe_export_state, stripe_export_template)
from repro.core.library import Library
from repro.core.scheduler import (Action, ContextAwareScheduler, ContextMode,
                                  Task)
from repro.core.store import (ContextStore, SnapshotPool, Tier,
                              TierFullError)
from repro.core.streaming import (ChunkCorruptionError, ChunkPlan, ChunkRef,
                                  StripeBuffer, assign_lanes, chunk_digest)
from repro.core.transfer import FetchSource, TransferPlan, TransferPlanner
from repro.core.transport import (Connection, Listener, Router,
                                  TransportError)

_PICKLE = pickle.HIGHEST_PROTOCOL


class Future:
    """Handle to one submitted task.

    Resolution is event-driven: worker threads (live backend) or the
    discrete-event loop (simulator backend) call ``set_result`` /
    ``set_exception``; ``result(timeout=...)`` blocks on a condition
    variable (live) or drives the event loop (sim) via ``backend.wait``.
    """

    def __init__(self, task_id: str, backend):
        self.task_id = task_id
        self._backend = backend
        self._value: Any = None
        self._ready = False
        self.error: Optional[BaseException] = None
        self._callbacks: List[Callable[["Future"], None]] = []
        self._cond = threading.Condition(threading.RLock())

    # ------------------------------------------------------- resolution ----
    def set_result(self, value: Any):
        with self._cond:
            if self._ready:
                return
            self._value = value
            self._ready = True
            self._cond.notify_all()
            self._fire_callbacks()

    def set_exception(self, error: BaseException):
        with self._cond:
            if self._ready:
                return
            self.error = error
            self._ready = True
            self._cond.notify_all()
            self._fire_callbacks()

    def _fire_callbacks(self):
        # fired from the resolving thread (a worker actor, holding runtime
        # locks): a raising user callback must never wedge the runtime
        callbacks, self._callbacks = self._callbacks, []
        for cb in callbacks:
            try:
                cb(self)
            except BaseException:
                traceback.print_exc(file=sys.stderr)

    def add_done_callback(self, cb: Callable[["Future"], None]):
        """Run ``cb(self)`` once the future resolves (immediately if it
        already has)."""
        with self._cond:
            if not self._ready:
                self._callbacks.append(cb)
                return
        cb(self)

    # --------------------------------------------------------- consumers ---
    def result(self, timeout: Optional[float] = None) -> Any:
        if not self._ready:
            self._backend.wait(self, timeout)
        if self.error is not None:
            raise self.error
        return self._value

    def _lost_message(self) -> str:
        task = self._backend.lookup_task(self.task_id)
        if task is None:
            return f"task {self.task_id} lost (unknown to the scheduler)"
        where = task.last_worker or "<never placed>"
        return (f"task {self.task_id} lost after {task.attempts} attempt(s); "
                f"last worker {where} — exceeded max_attempts or the pool "
                "drained with the task unfinished")

    @property
    def done(self) -> bool:
        return self._ready


_STOP = "stop"
_RETIRE = "retire"


class _StripeFetch:
    """Bookkeeping for one in-flight striped PEER transfer: which physical
    lanes exist (donor workers, plus an optional receiver-side pool lane),
    which lane currently OWNS each assignment lane's refs (ownership moves
    when a lane dies), and the receiver-side :class:`StripeBuffer` that
    verifies and assembles the chunks."""

    def __init__(self, stripe_id: int, recipe: ContextRecipe,
                 receiver_id: str, plan: Optional[TransferPlan],
                 donor_ids: tuple, n_pool: int):
        self.stripe_id = stripe_id
        self.recipe = recipe
        self.receiver_id = receiver_id
        self.plan = plan                  # planner TransferPlan (the flows)
        self.donor_ids = donor_ids        # assignment lane -> donor worker
        self.n_pool = n_pool
        self.buffer = StripeBuffer()
        self.failed_lanes: set = set()    # physical lanes that died
        # assignment lane -> physical lane responsible for its refs
        self.lane_owner: Dict[int, int] = {
            lane: lane for lane in range(len(donor_ids))}
        self.done = False


def _shutdown_at_exit(mgr_ref):
    """Join every worker thread before the interpreter (and the XLA
    runtime underneath it) tears down — a thread still inside a JAX call
    at exit aborts the process with 'terminate called without an active
    exception'."""
    mgr = mgr_ref()
    if mgr is not None:
        mgr.shutdown()


class LiveWorker:
    """One worker actor: a daemon thread + mailbox owning this worker's
    Library (materialized contexts) and ContextStore (residency
    bookkeeping).

    Mailbox messages are ``(kind, ...)`` tuples routed by the manager:

      ("start", task_id)              run one task invocation
      ("fetch", recipe, plan)         materialize/restore off-path (the
                                      POOL/DISK/FS/BUILD ladder rungs)
      ("donate", recipe, rcv, plan)   export this worker's warm context as
                                      a template snapshot and ship it to
                                      receiver ``rcv`` (monolithic PEER
                                      transfer — the donor keeps its copy
                                      serving)
      ("donate_chunks", sid, recipe,  streamed PEER: export a budget of
       rcv, spec)                     verified chunks of stripe ``sid``
                                      this turn, then repost the
                                      continuation to our own tail so
                                      queued serving work interleaves
      ("stripe_pool", sid, recipe,    serve immutable params chunks out of
       spec)                          the node SnapshotPool as an extra
                                      stripe lane (runs on the receiver)
      ("install_stripe", sid)         assemble stripe ``sid``'s chunks and
                                      promote the result (adopt)
      ("install", recipe, snap, plan  adopt a donated snapshot (restore to
       [, degraded_from])             device); ``snap=None`` degrades to
                                      the normal fetch ladder (logged as a
                                      degrade when ``degraded_from`` set)
      ("warm", recipe, event)         synchronous warm-up (event set when
                                      resident)
      ("demote", key, tier, event)    physically demote one context
      ("retire",)                     device reclaimed: demote everything
                                      to the node snapshot pool and exit
      ("stop",)                       plain shutdown (no demotion)

    The thread executes messages strictly in order, so a preemption that
    lands mid-invocation simply marks the worker dead (``alive=False``):
    the in-flight result is discarded at the revalidation barrier and the
    retirement demotion runs right after the current message finishes —
    no state is ever snapshotted mid-mutation.
    """

    def __init__(self, worker_id: str, manager: "PCMManager", profile=None):
        self.worker_id = worker_id
        self.profile = profile          # cluster.devices.DeviceProfile
        self.library = Library(worker_id, snapshots=manager.snapshots,
                               streamed=manager.streamed)
        hbm_gb = getattr(profile, "hbm_gb", None)
        self.store = ContextStore(device_bytes=int(hbm_gb * GB)) \
            if hbm_gb else ContextStore()
        self.mailbox: "queue.SimpleQueue" = queue.SimpleQueue()
        self.alive = True
        self._mgr = manager
        self._thread = threading.Thread(
            target=self._run, name=f"pcm-worker-{worker_id}", daemon=True)

    def start(self):
        self._thread.start()

    def post(self, msg: tuple):
        self.mailbox.put(msg)

    def join(self, timeout: Optional[float] = None):
        self._thread.join(timeout)

    # ------------------------------------------------------------ thread ---
    def _run(self):
        while True:
            msg = self.mailbox.get()
            kind = msg[0]
            if kind == _STOP:
                self._mgr._absorb_library(self.library)
                break
            if kind == _RETIRE:
                try:
                    self.library.demote_all(force=True)
                except BaseException:
                    traceback.print_exc(file=sys.stderr)
                self._mgr._absorb_library(self.library)
                break
            try:
                if kind == "start":
                    self._handle_start(msg[1])
                elif kind == "fetch":
                    self._handle_fetch(msg[1], msg[2])
                elif kind == "donate":
                    self._handle_donate(msg[1], msg[2], msg[3])
                elif kind == "donate_chunks":
                    self._handle_donate_chunks(msg[1], msg[2], msg[3],
                                               msg[4])
                elif kind == "stripe_pool":
                    self._handle_stripe_pool(msg[1], msg[2], msg[3])
                elif kind == "install_stripe":
                    self._handle_install_stripe(msg[1])
                elif kind == "install":
                    self._handle_install(msg[1], msg[2], msg[3],
                                         msg[4] if len(msg) > 4 else None)
                elif kind == "install_wire":
                    self._handle_install_wire(msg[1], msg[2], msg[3],
                                              msg[4] if len(msg) > 4
                                              else None)
                elif kind == "warm":
                    self._handle_warm(msg[1], msg[2], msg[3])
                elif kind == "demote":
                    self._handle_demote(msg[1], msg[2], msg[3], msg[4])
            except BaseException:
                traceback.print_exc(file=sys.stderr)
        self._drain_events()

    def _drain_events(self):
        # a retiring worker must not strand synchronous callers or wedge
        # the transfer pipeline: release every event still waiting in the
        # mailbox, degrade pending donations so their receivers fall back
        # down the ladder, and free every planner flow we would have
        # completed
        while True:
            try:
                msg = self.mailbox.get_nowait()
            except queue.Empty:
                return
            kind = msg[0]
            if kind == "donate":
                # the receiver is still FETCHING on our donation: hand it
                # a None snapshot so it degrades to pool/disk/builder
                self._mgr._deliver_install(msg[2], msg[1], None, msg[3],
                                           degraded_from=FetchSource.PEER)
            elif kind == "donate_chunks":
                self._mgr._stripe_lane_lost(
                    msg[1], msg[4].get("via_lane", msg[4]["lane"]))
            elif kind == "stripe_pool":
                self._mgr._stripe_lane_lost(msg[1], msg[3]["lane"])
            elif kind == "install_stripe":
                self._mgr._stripe_failed(msg[1])
            elif kind == "fetch":
                self._mgr._flow_done(msg[2], failed=True)
            elif kind in ("install", "install_wire"):
                self._mgr._flow_done(msg[3], failed=True)
            for part in msg:
                if isinstance(part, threading.Event):
                    part.set()

    # ---------------------------------------------------------- handlers ---
    def _handle_start(self, task_id: str):
        mgr = self._mgr
        with mgr._lock:
            entry = mgr.scheduler.running.get(task_id)
            if not self.alive or entry is None or entry[0] != self.worker_id:
                return                    # cancelled / reassigned / dead
            task = mgr.scheduler.tasks[task_id]
            fn, args, kwargs = task.payload
            named = dict(zip(task.context_names, task.recipes))
        # the invocation (context build/restore + user fn) runs OUTSIDE the
        # manager lock: other workers keep dispatching and completing
        value: Any = None
        error: Optional[BaseException] = None
        try:
            value = self.library.invoke(fn, args, kwargs,
                                        recipes=named or None,
                                        task_id=task_id)
        except BaseException as e:       # report, don't wedge the pool
            error = e
        with mgr._cond:
            self._drain_stage_obs_locked()
            entry = mgr.scheduler.running.get(task_id)
            if not self.alive or entry is None or entry[0] != self.worker_id:
                # preempted or cancelled while running: the scheduler has
                # already requeued/completed elsewhere — discard this copy
                return
            if mgr.mode == ContextMode.AGNOSTIC:
                self.library.evict_all()
            elif mgr.mode == ContextMode.PARTIAL:
                for key in task.keys():
                    self.library.evict(key)
            fut = mgr._futures.get(task.duplicates_of or task_id)
            if fut is not None:
                if error is None:
                    fut.set_result(value)
                else:
                    fut.set_exception(error)
            acts = mgr.scheduler.on_task_done(self.worker_id, task_id,
                                              mgr.now)
            mgr._fail_unresolved()
            mgr._dispatch(acts)
            mgr._cond.notify_all()

    def _handle_fetch(self, recipe: ContextRecipe,
                      plan: Optional[TransferPlan]):
        mgr = self._mgr
        if not self.alive:
            mgr._flow_done(plan, failed=True)
            return           # preempted with the fetch still queued: the
            # scheduler already forgot this worker — don't burn a build
        key = recipe.key()
        failed = False
        try:
            self.library.ensure(recipe)
        except BaseException:
            traceback.print_exc(file=sys.stderr)
            failed = True
        with mgr._cond:
            # no bandwidth calibration here: the ladder fallback may have
            # run the builder, which says nothing about a transfer rate
            mgr._flow_done_locked(plan, failed=failed)
            self._drain_stage_obs_locked()
            if not self.alive:
                return
            # a failed build reports a non-matching key: the scheduler
            # clears the fetching state without recording residency
            acts = mgr.scheduler.on_fetch_done(
                self.worker_id, "<build-failed>" if failed else key, mgr.now)
            mgr._dispatch(acts)
            mgr._cond.notify_all()

    def _handle_donate(self, recipe: ContextRecipe, receiver_id: str,
                       plan: Optional[TransferPlan]):
        """Donor side of a PEER transfer: export a template snapshot of
        the warm context (non-destructive — this worker keeps serving from
        its own copy) and ship it to the receiver's mailbox. A donor that
        lost the context (race with eviction/preemption) or whose export
        fails degrades the receiver to the normal fetch ladder."""
        mgr = self._mgr
        key = recipe.key()
        snap = None
        if self.alive and self.library.has(key):
            try:
                snap = export_context(self.library.context(key))
                self.library.peer_exports += 1
            except BaseException:
                traceback.print_exc(file=sys.stderr)
        mgr._deliver_install(receiver_id, recipe, snap, plan,
                             degraded_from=None if snap is not None
                             else FetchSource.PEER)

    def _export_budget(self) -> Optional[int]:
        """Chunks this donor may export in ONE mailbox turn, tied to its
        queue depth: an idle donor drains its lane in one go (None = no
        cap); a donor with queued serving work exports fewer chunks per
        turn the deeper its mailbox, so decode latency under fanout stays
        bounded by a few chunk ``device_get``s."""
        depth = self.mailbox.qsize()
        if depth <= 0:
            return None
        return max(1, self._mgr.export_chunk_budget // (1 + depth))

    def _drain_stage_obs_locked(self):
        """Feed per-stage (disk/h2d) timings observed by this worker's
        streamed restores into the planner's pipeline calibration (callers
        hold the manager lock)."""
        obs, self.library.stage_observations = \
            self.library.stage_observations, []
        for stage, nbytes, seconds in obs:
            self._mgr.planner.observe_stage(stage, nbytes, seconds)

    def _handle_donate_chunks(self, stripe_id: int, recipe: ContextRecipe,
                              receiver_id: str, spec: dict):
        """Donor lane of a STREAMED peer transfer: recompute the
        deterministic ChunkPlan over this context's device half (plans
        depend on template shapes alone, so every donor and the manager
        agree with zero coordination), export up to a budget of chunks
        this turn — each a per-chunk ``device_get`` + sha256 — then repost
        the continuation to our own mailbox TAIL so serving work queued
        behind this message runs between export turns. The primary lane
        additionally ships the template metadata (structural clone sharing
        our AOT executables + synthesized host halves) before its first
        chunk."""
        mgr = self._mgr
        key = recipe.key()
        lane = spec["lane"]                      # assignment lane
        via = spec.get("via_lane", lane)         # physical lane doing work
        with mgr._lock:
            sf = mgr._stripes.get(stripe_id)
        if sf is None or sf.done:
            return                               # stripe already concluded
        if not (self.alive and self.library.has(key)):
            mgr._stripe_lane_lost(stripe_id, via)
            return
        t0 = time.monotonic()
        sent = 0
        try:
            ctx = self.library.context(key)
            device = stripe_export_state(ctx)
            plan = ChunkPlan(device, chunk_bytes=mgr.chunk_bytes)
            if spec.get("with_template"):
                clone, host_halves, host_nbytes = stripe_export_template(ctx)
                self.library.peer_exports += 1
                mgr._stripe_template(stripe_id, plan, clone, host_halves,
                                     host_nbytes + plan.total_bytes,
                                     ctx.build_seconds, ctx.aot_seconds,
                                     device_tree=device)
                spec = dict(spec, with_template=False)
            if spec.get("ref_ids") is not None:
                refs = [r for r in plan.refs if r.id in spec["ref_ids"]]
            else:
                refs = assign_lanes(plan.refs, spec["n_donor"],
                                    spec["n_pool"])[lane]
            cursor = spec.get("cursor", 0)
            budget = self._export_budget()
            stop = len(refs) if budget is None \
                else min(len(refs), cursor + budget)
            flat = ChunkPlan.flat_map(device)
            while cursor < stop:
                ref = refs[cursor]
                # np.asarray of the device-array slice IS the per-chunk
                # device_get — the only point this turn touches the device
                piece = np.asarray(plan.extract(flat, ref))
                sent += int(piece.nbytes)
                if not mgr._stripe_deliver(stripe_id, ref, piece,
                                           chunk_digest(piece), via):
                    return               # lane failed or stripe concluded
                cursor += 1
        except BaseException:
            traceback.print_exc(file=sys.stderr)
            mgr._stripe_lane_lost(stripe_id, via)
            return
        finally:
            elapsed = time.monotonic() - t0
            sf.buffer.add_lane_seconds(via, elapsed)
            if sent:
                with mgr._lock:
                    mgr.planner.observe_stage("d2h", sent, elapsed)
        if cursor < len(refs):
            self.post(("donate_chunks", stripe_id, recipe, receiver_id,
                       dict(spec, cursor=cursor)))
        # else: lane drained — the install fires from the last delivery

    def _handle_stripe_pool(self, stripe_id: int, recipe: ContextRecipe,
                            spec: dict):
        """Receiver-side pool lane of a striped fetch: serve the immutable
        ``params`` chunks straight out of the node SnapshotPool — HOST_RAM
        slices, or per-entry verified reads of a spilled snapshot — while
        donor lanes carry the rest. Activated only after the template
        lands (the plan must exist). Any failure loses this lane only: its
        refs reassign to a surviving donor lane."""
        mgr = self._mgr
        lane = spec["lane"]
        with mgr._lock:
            sf = mgr._stripes.get(stripe_id)
        if sf is None or sf.done:
            return
        if not self.alive:
            mgr._stripe_lane_lost(stripe_id, lane)
            return
        t0 = time.monotonic()
        try:
            plan = sf.buffer.plan
            refs = sf.buffer.missing_refs(
                assign_lanes(plan.refs, spec["n_donor"],
                             spec["n_pool"])[lane])
            if not refs:
                return
            snap = mgr.snapshots.peek(recipe.key())
            if snap is None:
                raise LookupError(
                    f"pool snapshot for {recipe.key()} gone before the "
                    "stripe lane could read it")
            if snap.spilled:
                needed = {r.key for r in refs}
                flat = dict(mgr.snapshots.spill_store().iter_entries(
                    snap.spill_key, keys=needed))
            else:
                flat = ChunkPlan.flat_map(
                    {name: {"params": comp["params"]}
                     for name, comp in snap.host_state.items()
                     if isinstance(comp, dict) and "params" in comp})
            mgr.snapshots.stripe_reads += len(refs)
            for ref in refs:
                piece = np.asarray(plan.extract(flat, ref))
                if not mgr._stripe_deliver(stripe_id, ref, piece,
                                           chunk_digest(piece), lane):
                    return
        except BaseException:
            traceback.print_exc(file=sys.stderr)
            mgr._stripe_lane_lost(stripe_id, lane)
        finally:
            sf.buffer.add_lane_seconds(lane, time.monotonic() - t0)

    def _handle_install_stripe(self, stripe_id: int):
        """Receiver end of a striped transfer: assemble the verified
        chunks into a template snapshot and promote it (adopt — zero
        builder calls, zero compiles, exactly like the monolithic PEER
        install)."""
        mgr = self._mgr
        with mgr._lock:
            sf = mgr._stripes.get(stripe_id)
        if sf is None:
            return
        if not self.alive:
            mgr._stripe_failed(stripe_id)
            return
        key = sf.recipe.key()
        failed = False
        measured = None
        try:
            buf = sf.buffer
            host_state = buf.assemble()
            snap = ContextSnapshot(
                recipe=sf.recipe, value=buf.clone, host_state=host_state,
                nbytes=buf.nbytes, build_seconds=buf.build_seconds,
                aot_seconds=buf.aot_seconds,
                demote_seconds=buf.export_seconds)
            ctx = restore_context(snap, self.worker_id)
            self.library.adopt(ctx)
            # same calibration contract as the monolithic install: export
            # work (slowest lane) + restore work, never queue wait
            measured = snap.demote_seconds + ctx.restore_seconds
        except BaseException:
            traceback.print_exc(file=sys.stderr)
            failed = True
            measured = None
        with mgr._cond:
            mgr._stripes.pop(stripe_id, None)
            sf.done = True
            mgr._cancel_remote_lanes(sf)
            mgr._flow_done_locked(sf.plan, measured_seconds=measured,
                                  failed=failed)
            self._drain_stage_obs_locked()
            if not self.alive:
                return
            acts = mgr.scheduler.on_fetch_done(
                self.worker_id, "<transfer-failed>" if failed else key,
                mgr.now)
            mgr._dispatch(acts)
            mgr._cond.notify_all()

    def _handle_install(self, recipe: ContextRecipe, snap,
                        plan: Optional[TransferPlan],
                        degraded_from: Optional[FetchSource] = None):
        """Receiver side of a PEER transfer: promote the donated snapshot
        to the device and adopt it (zero builder calls, zero compiles).
        ``snap=None`` means the donor could not serve — fall back down the
        ladder (pool -> disk -> builder) via ``Library.ensure``, recorded
        in the scheduler's fetch_log as a degrade from ``degraded_from``
        when set."""
        mgr = self._mgr
        if not self.alive:
            mgr._flow_done(plan, failed=True)
            return
        key = recipe.key()
        failed = False
        measured = None
        try:
            if snap is not None:
                ctx = restore_context(snap, self.worker_id)
                self.library.adopt(ctx)
                # calibrate on the transfer WORK (donor export + receiver
                # restore), not end-to-end latency: mailbox queue wait —
                # or a builder run on a degraded donation — is not
                # bandwidth
                measured = snap.demote_seconds + ctx.restore_seconds
            else:
                self.library.ensure(recipe)
        except BaseException:
            traceback.print_exc(file=sys.stderr)
            failed = True
            measured = None
        with mgr._cond:
            mgr._flow_done_locked(plan, measured_seconds=measured,
                                  failed=failed)
            self._drain_stage_obs_locked()
            if not self.alive:
                return
            if snap is None and not failed and degraded_from is not None:
                # the ladder fallback actually acquired the context — log
                # where it landed so fetch_history stays a complete account
                mgr.scheduler.record_degrade(
                    self.worker_id, key, self.library.fetch_sources[-1],
                    mgr.now, degraded_from=degraded_from)
            acts = mgr.scheduler.on_fetch_done(
                self.worker_id, "<transfer-failed>" if failed else key,
                mgr.now)
            mgr._dispatch(acts)
            mgr._cond.notify_all()

    def _handle_install_wire(self, recipe: ContextRecipe, blob: bytes,
                             plan: Optional[TransferPlan],
                             degraded_from: Optional[FetchSource] = None):
        """Receiver side of a PEER transfer whose snapshot arrived as a
        WIRE blob (the donor is a remote process; the manager forwards the
        bytes without materializing them). Decode locally — chunk-level
        sha256 verification plus AOTRecipe component reconstruction — then
        delegate to the one install codepath. A decode failure degrades to
        the normal fetch ladder exactly like a failed donation."""
        snap = None
        try:
            snap = pcm_wire.decode_snapshot(blob)
        except BaseException:
            traceback.print_exc(file=sys.stderr)
        self._handle_install(recipe, snap, plan,
                             degraded_from if snap is not None
                             else (degraded_from or FetchSource.PEER))

    def _handle_warm(self, recipe: ContextRecipe, event: threading.Event,
                     errors: List[BaseException]):
        mgr = self._mgr
        try:
            self.library.ensure(recipe)
            with mgr._lock:
                if self.alive:
                    self.store.admit_recipe(recipe, mgr.mode.persist_tier,
                                            now=mgr.now)
        except BaseException as e:       # surfaced by warm_up in the caller
            errors.append(e)
        finally:
            event.set()

    def _handle_demote(self, key: str, tier: Tier, event: threading.Event,
                       demoted: List[str]):
        mgr = self._mgr
        try:
            snap = self.library.demote(key)   # None when absent or pinned
            if snap is not None and tier == Tier.LOCAL_DISK:
                mgr.snapshots.spill(key)
            with mgr._lock:
                if snap is not None:
                    demoted.append(self.worker_id)
                    self.store.drop(key, down_to=tier)
                    try:
                        self.store.admit(key, tier, snap.nbytes,
                                         now=mgr.now)
                    except TierFullError:
                        # bookkeeping refused (pin-blocked tier); the
                        # snapshot is in the pool regardless — the worker
                        # just shows as cold to the placement ladder.
                        # Other ValueErrors are admission bugs: propagate.
                        pass
        finally:
            event.set()


class _MirrorRecord:
    """Invocation record replayed from a remote worker's status reports —
    just the field the manager aggregates (cold vs warm)."""

    __slots__ = ("cold",)

    def __init__(self, cold: bool):
        self.cold = cold


class _RemoteLibraryMirror:
    """Manager-side view of a remote worker's Library.

    The real Library lives in the node process; every reply frame carries a
    status dict (absolute counters, plus deltas of invocation records,
    fetch sources and stage observations) that this mirror folds in. It
    duck-types the Library surface the manager reads — counters for
    ``stats()``/``_absorb_library``, ``has()`` for demotion targeting,
    ``pin``/``unpin`` (forwarded as frames) — so PCMManager code paths stay
    identical for local and remote workers.
    """

    def __init__(self, worker_id: str, send: Callable):
        self.worker_id = worker_id
        self._send = send
        self._lock = threading.Lock()
        self._resident: set = set()
        self.pinned: set = set()
        self.records: List[_MirrorRecord] = []
        self.fetch_sources: List[FetchSource] = []
        self.stage_observations: List[tuple] = []
        self.build_seconds_total = 0.0
        self.restore_seconds_total = 0.0
        self.aot_seconds_total = 0.0
        self.builder_calls = 0
        self.restores = 0
        self.demotions = 0
        self.peer_installs = 0
        self.peer_exports = 0
        self.peer_install_seconds = 0.0
        self.absorbed = False

    def has(self, key: str) -> bool:
        with self._lock:
            return key in self._resident

    @property
    def resident_keys(self):
        with self._lock:
            return set(self._resident)

    def pin(self, key: str):
        self.pinned.add(key)
        self._send("pin", {"key": key})

    def unpin(self, key: str):
        self.pinned.discard(key)
        self._send("unpin", {"key": key})

    def update(self, status: Optional[Dict], mgr: "PCMManager"):
        """Fold one status report in. Counters are ABSOLUTE (idempotent
        under frame reordering-free TCP); records/sources/stage timings
        are node-side deltas, appended."""
        if not status:
            return
        stage_obs = status.get("stage_obs") or []
        with self._lock:
            for k, v in (status.get("counters") or {}).items():
                if hasattr(self, k) and not k.startswith("_"):
                    setattr(self, k, v)
            for cold in status.get("records") or []:
                self.records.append(_MirrorRecord(bool(cold)))
            for name in status.get("sources") or []:
                try:
                    self.fetch_sources.append(FetchSource[name])
                except KeyError:
                    pass
            if "resident" in status:
                self._resident = set(status.get("resident") or [])
        if stage_obs:
            with mgr._lock:
                for stage, nbytes, secs in stage_obs:
                    mgr.planner.observe_stage(stage, int(nbytes),
                                              float(secs))


class _RemoteStripeTracker:
    """StripeBuffer stand-in when a stripe's RECEIVER is a remote worker.

    Chunks still funnel through ``PCMManager._stripe_deliver`` (one
    codepath for fault injection, lane accounting and install triggering),
    but instead of buffering them this tracker re-verifies each digest and
    FORWARDS the chunk over the receiver's connection; the node process
    runs the real :class:`StripeBuffer` and does the assemble/restore.
    ``complete()`` therefore means "every expected ref was forwarded" —
    the node's STRIPE_DONE/STRIPE_LANE_LOST frames reconcile the
    authoritative receiver-side view back into this one.
    """

    def __init__(self, mgr: "PCMManager", stripe_id: int, worker):
        self._mgr = mgr
        self._sid = stripe_id
        self._worker = worker
        self._tlock = threading.Lock()
        self._expected: Optional[Dict] = None
        self._forwarded: set = set()
        self.plan: Optional[ChunkPlan] = None
        self.clone = None
        self.host_halves = None
        self.nbytes = 0
        self.build_seconds = 0.0
        self.aot_seconds = 0.0
        self.lane_seconds: Dict[int, float] = {}
        self.chunks_delivered = 0
        self.install_posted = False     # guarded by the manager's lock

    # ------------------------------------------------------------ filling --
    def set_template_remote(self, plan: ChunkPlan, recipe, chunk_bytes: int,
                            clone, host_halves, nbytes: int,
                            build_seconds: float, aot_seconds: float,
                            device_tree=None,
                            wire_blob: Optional[bytes] = None):
        with self._tlock:
            self.plan = plan
            self.nbytes = nbytes
            self.build_seconds = build_seconds
            self.aot_seconds = aot_seconds
            self._expected = {r.id: r for r in plan.refs}
        sid, mgr = self._sid, self._mgr
        conn = self._worker.conn
        if wire_blob is not None:
            # remote donor -> remote receiver: the blob passes through
            # verbatim (the manager only decoded its spec section)
            conn.send("stripe_template", {"sid": sid}, wire_blob)
            return

        def thunk():
            # local donor -> remote receiver: wire-encode on the WRITER
            # thread (host-half pack + pickles; the spec map reads only
            # shapes/dtypes — no device_get here)
            try:
                blob = pcm_wire.encode_template(
                    recipe, clone, host_halves, device_tree, nbytes,
                    build_seconds, aot_seconds, chunk_bytes=chunk_bytes)
            except BaseException:
                traceback.print_exc(file=sys.stderr)
                mgr._stripe_failed(sid)
                return None
            return ("stripe_template", {"sid": sid}, blob)

        conn.send_lazy(thunk)

    def deliver(self, ref: ChunkRef, array, sha: str, lane: int = 0):
        arr = np.asarray(array)
        if chunk_digest(arr) != sha:
            raise ChunkCorruptionError(
                f"stripe chunk {ref.index} of {ref.key!r} from lane {lane} "
                "failed verification (forwarding)")
        with self._tlock:
            if ref.id in self._forwarded:
                return
            self._forwarded.add(ref.id)
            self.chunks_delivered += 1
        meta = {"sid": self._sid,
                "ref": [ref.key, ref.index, ref.count, ref.axis,
                        ref.start, ref.stop],
                "sha": sha, "lane": lane,
                "dtype": arr.dtype.str, "shape": list(arr.shape)}
        self._worker.conn.send_lazy(
            lambda: ("stripe_chunk", meta,
                     np.ascontiguousarray(arr).tobytes()))

    def add_lane_seconds(self, lane: int, seconds: float):
        with self._tlock:
            self.lane_seconds[lane] = \
                self.lane_seconds.get(lane, 0.0) + seconds

    # ----------------------------------------------------------- querying --
    def complete(self) -> bool:
        with self._tlock:
            return (self._expected is not None
                    and len(self._forwarded) >= len(self._expected))

    def missing_refs(self, assigned: List[ChunkRef]) -> List[ChunkRef]:
        with self._tlock:
            return [r for r in assigned if r.id not in self._forwarded]

    def reconcile(self, delivered_ids):
        """Replace the forwarded set with the NODE's verified set (frames
        queued but lost with a dying lane must be re-forwarded)."""
        with self._tlock:
            self._forwarded = set(delivered_ids)

    @property
    def export_seconds(self) -> float:
        with self._tlock:
            return max(self.lane_seconds.values(), default=0.0)


class RemoteWorker:
    """Manager-side proxy for a worker living in another OS process.

    Duck-types :class:`LiveWorker` where the manager touches it (``post``,
    ``alive``, ``store``, ``library``, ``profile``, ``join``): ``post``
    translates the mailbox vocabulary into transport frames — expensive
    encodes (task pickles, snapshot wire blobs) deferred to the
    connection's writer thread via ``send_lazy`` so nothing heavy ever
    runs under the manager lock — and the reply frames replay the exact
    completion blocks a LiveWorker would have run under ``mgr._cond``.
    The node orders frames like a mailbox (single consumer, in order), so
    preemption/retire semantics carry over unchanged.
    """

    is_remote = True

    def __init__(self, worker_id: str, manager: "PCMManager", profile=None):
        self.worker_id = worker_id
        self.profile = profile
        self._mgr = manager
        self.conn: Optional[Connection] = None     # set before start
        self.library = _RemoteLibraryMirror(worker_id, self._send)
        hbm_gb = getattr(profile, "hbm_gb", None)
        self.store = ContextStore(device_bytes=int(hbm_gb * GB)) \
            if hbm_gb else ContextStore()
        self.alive = True
        self._tokens = itertools.count()
        self._pending: Dict[int, tuple] = {}
        self._plock = threading.Lock()
        self._finalized = False
        self._closed_evt = threading.Event()

    def _send(self, kind: str, meta: Dict, payload: bytes = b""):
        if self.conn is not None and not self.conn.closed:
            self.conn.send(kind, meta, payload)

    def join(self, timeout: Optional[float] = None):
        # unlike a thread join, an unresponsive REMOTE process must not
        # wedge shutdown forever: cap the default wait
        self._closed_evt.wait(timeout if timeout is not None else 10.0)

    # -------------------------------------------------- mailbox -> frames --
    def post(self, msg: tuple):
        kind = msg[0]
        if kind == "start":
            self._post_start(msg[1])
        elif kind == "fetch":
            self._post_fetch(msg[1], msg[2])
        elif kind == "donate":
            self._post_donate(msg[1], msg[2], msg[3])
        elif kind == "donate_chunks":
            self._post_donate_chunks(msg[1], msg[2], msg[3], msg[4])
        elif kind == "install":
            self._post_install(msg[1], msg[2], msg[3],
                               msg[4] if len(msg) > 4 else None)
        elif kind == "install_wire":
            self._post_install_wire(msg[1], msg[2], msg[3],
                                    msg[4] if len(msg) > 4 else None)
        elif kind == "install_stripe":
            self._send("install_stripe", {"sid": msg[1]})
        elif kind == "warm":
            self._post_warm(msg[1], msg[2], msg[3])
        elif kind == "demote":
            self._post_demote(msg[1], msg[2], msg[3], msg[4])
        elif kind == _RETIRE:
            self._send("retire", {})
        elif kind == _STOP:
            self._send("stop", {})
        else:                            # e.g. "stripe_pool" never routes here
            print(f"RemoteWorker({self.worker_id}): unroutable mailbox "
                  f"message {kind!r}", file=sys.stderr)

    def _pool_promotion_thunk(self, recipe: ContextRecipe):
        """Writer-thread resolve of the manager-pool rung for a task
        heading to this node. In-process workers share the manager's
        SnapshotPool through their Library, so a task-time ``ensure``
        promotes a demoted context transparently; the node's library has
        its OWN pool, so a pooled snapshot must cross the wire — queued
        BEFORE the task frame, it is resident by the time the task runs."""
        mgr = self._mgr
        key = recipe.key()

        def thunk():
            if self.library.has(key):
                return None
            snap = mgr.snapshots.take(key)
            if snap is None:
                return None
            src = "DISK" if snap.spilled else "POOL"
            try:
                if snap.spilled:
                    snap.unspill(mgr.snapshots.spill_store())
                blob = pcm_wire.encode_snapshot(
                    snap, chunk_bytes=mgr.chunk_bytes)
            except BaseException:
                traceback.print_exc(file=sys.stderr)
                return None        # node falls down its own ladder
            return ("install", {"token": -1, "key": key, "op": "promote",
                                "source": src, "wire": True}, blob)

        return thunk

    def _post_start(self, task_id: str):
        mgr = self._mgr
        with mgr._lock:
            task = mgr.scheduler.tasks.get(task_id)
            if task is None:
                return
            payload = (task.payload,
                       dict(zip(task.context_names, task.recipes)))
        for recipe in payload[1].values():
            if not self.library.has(recipe.key()):
                self.conn.send_lazy(self._pool_promotion_thunk(recipe))

        def thunk():
            try:
                return ("task", {"task_id": task_id},
                        pickle.dumps(payload, _PICKLE))
            except BaseException as exc:
                self._task_failed_local(task_id, RuntimeError(
                    f"task {task_id} payload is not picklable for remote "
                    f"worker {self.worker_id}: {exc}"))
                return None

        self.conn.send_lazy(thunk)

    def _task_failed_local(self, task_id: str, error: BaseException):
        mgr = self._mgr
        with mgr._cond:
            entry = mgr.scheduler.running.get(task_id)
            if not self.alive or entry is None \
                    or entry[0] != self.worker_id:
                return
            task = mgr.scheduler.tasks[task_id]
            fut = mgr._futures.get(task.duplicates_of or task_id)
            if fut is not None:
                fut.set_exception(error)
            acts = mgr.scheduler.on_task_done(self.worker_id, task_id,
                                              mgr.now)
            mgr._fail_unresolved()
            mgr._dispatch(acts)
            mgr._cond.notify_all()

    def _post_fetch(self, recipe: ContextRecipe,
                    plan: Optional[TransferPlan]):
        token = next(self._tokens)
        with self._plock:
            self._pending[token] = ("fetch", recipe, plan, None)
        mgr = self._mgr
        key = recipe.key()

        def thunk():
            # the POOL/DISK rungs live in the MANAGER's node pool: resolve
            # them here (writer thread) and ship the snapshot as a wire
            # blob; anything else falls to the node's own FS/BUILD ladder
            snap = mgr.snapshots.take(key)
            if snap is not None:
                src = "DISK" if snap.spilled else "POOL"
                try:
                    if snap.spilled:
                        snap.unspill(mgr.snapshots.spill_store())
                    blob = pcm_wire.encode_snapshot(
                        snap, chunk_bytes=mgr.chunk_bytes)
                    return ("install", {"token": token, "key": key,
                                        "op": "fetch", "source": src,
                                        "wire": True}, blob)
                except BaseException:
                    traceback.print_exc(file=sys.stderr)
            return ("fetch", {"token": token, "key": key},
                    pickle.dumps(recipe, _PICKLE))

        self.conn.send_lazy(thunk)

    def _post_donate(self, recipe: ContextRecipe, receiver_id: str,
                     plan: Optional[TransferPlan]):
        token = next(self._tokens)
        with self._plock:
            self._pending[token] = ("donate", recipe, plan, receiver_id)
        self._send("donate", {"token": token, "key": recipe.key()})

    def _post_donate_chunks(self, stripe_id: int, recipe: ContextRecipe,
                            receiver_id: str, spec: dict):
        spec_w = dict(spec)
        if spec_w.get("ref_ids") is not None:
            spec_w["ref_ids"] = [list(t) for t in spec_w["ref_ids"]]

        def thunk():
            return ("donate_chunks",
                    {"sid": stripe_id, "key": recipe.key(),
                     "spec": spec_w},
                    pickle.dumps(recipe, _PICKLE))

        self.conn.send_lazy(thunk)

    def _post_install(self, recipe: ContextRecipe, snap,
                      plan: Optional[TransferPlan],
                      degraded_from: Optional[FetchSource]):
        token = next(self._tokens)
        with self._plock:
            self._pending[token] = ("install", recipe, plan, degraded_from)
        key = recipe.key()
        mgr = self._mgr

        def thunk():
            if snap is not None:
                try:
                    if snap.spilled:
                        snap.unspill(mgr.snapshots.spill_store())
                    blob = pcm_wire.encode_snapshot(
                        snap, chunk_bytes=mgr.chunk_bytes)
                    return ("install", {"token": token, "key": key,
                                        "op": "install", "source": "PEER",
                                        "wire": True}, blob)
                except BaseException:
                    traceback.print_exc(file=sys.stderr)
            dfrom = degraded_from or (FetchSource.PEER if snap is not None
                                      else None)
            return ("install",
                    {"token": token, "key": key, "op": "install",
                     "wire": False,
                     "degraded_from": dfrom.name if dfrom else None},
                    pickle.dumps(recipe, _PICKLE))

        self.conn.send_lazy(thunk)

    def _post_install_wire(self, recipe: ContextRecipe, blob: bytes,
                           plan: Optional[TransferPlan],
                           degraded_from: Optional[FetchSource]):
        token = next(self._tokens)
        with self._plock:
            self._pending[token] = ("install", recipe, plan, degraded_from)
        self._send("install", {"token": token, "key": recipe.key(),
                               "op": "install", "source": "PEER",
                               "wire": True}, blob)

    def _post_warm(self, recipe: ContextRecipe, event: threading.Event,
                   errors: List[BaseException]):
        token = next(self._tokens)
        with self._plock:
            self._pending[token] = ("warm", event, errors, recipe)
        if not self.library.has(recipe.key()):
            self.conn.send_lazy(self._pool_promotion_thunk(recipe))

        def thunk():
            try:
                return ("warm", {"token": token},
                        pickle.dumps(recipe, _PICKLE))
            except BaseException as exc:
                with self._plock:
                    self._pending.pop(token, None)
                errors.append(RuntimeError(
                    f"recipe not picklable for remote worker "
                    f"{self.worker_id}: {exc}"))
                event.set()
                return None

        self.conn.send_lazy(thunk)

    def _post_demote(self, key: str, tier: Tier, event: threading.Event,
                     demoted: List[str]):
        token = next(self._tokens)
        with self._plock:
            self._pending[token] = ("demote", event, demoted, key, tier)
        self._send("demote", {"token": token, "key": key,
                              "tier": int(tier)})

    # ------------------------------------------------- frames -> replies ---
    def _on_frame(self, conn, kind: str, meta: Dict, payload: bytes):
        handler = getattr(self, f"_h_{kind}", None)
        if handler is None:
            print(f"RemoteWorker({self.worker_id}): unknown frame "
                  f"{kind!r}", file=sys.stderr)
            return
        handler(meta, payload)

    def _pop(self, token) -> Optional[tuple]:
        with self._plock:
            return self._pending.pop(token, None)

    def _h_result(self, meta: Dict, payload: bytes):
        mgr = self._mgr
        self.library.update(meta.get("status"), mgr)
        ok = bool(meta.get("ok"))
        value = error = None
        try:
            obj = pickle.loads(payload)
        except BaseException as exc:
            ok, obj = False, RuntimeError(
                f"result from {self.worker_id} failed to unpickle: {exc}")
        if ok:
            value = obj
        else:
            error = obj if isinstance(obj, BaseException) \
                else RuntimeError(str(obj))
        task_id = meta["task_id"]
        with mgr._cond:
            entry = mgr.scheduler.running.get(task_id)
            if not self.alive or entry is None \
                    or entry[0] != self.worker_id:
                return               # preempted/reassigned: discard copy
            task = mgr.scheduler.tasks[task_id]
            fut = mgr._futures.get(task.duplicates_of or task_id)
            if fut is not None:
                if error is None:
                    fut.set_result(value)
                else:
                    fut.set_exception(error)
            acts = mgr.scheduler.on_task_done(self.worker_id, task_id,
                                              mgr.now)
            mgr._fail_unresolved()
            mgr._dispatch(acts)
            mgr._cond.notify_all()

    def _h_done(self, meta: Dict, payload: bytes):
        mgr = self._mgr
        # fold the status FIRST: records/sources are node-side
        # DELTAS — discarding a reply (stale token) must not
        # drop them
        self.library.update(meta.get("status"), mgr)
        info = self._pop(meta["token"])
        if info is None:
            return
        op, recipe, plan, degraded_from = info
        ok = bool(meta.get("ok"))
        key = recipe.key()
        degraded = bool(meta.get("degraded"))
        measured = meta.get("measured") \
            if (ok and op == "install" and not degraded) else None
        with mgr._cond:
            mgr._flow_done_locked(plan, measured_seconds=measured,
                                  failed=not ok)
            if not self.alive:
                mgr._cond.notify_all()
                return
            if ok and degraded:
                dfrom = degraded_from
                if dfrom is None and meta.get("degraded_from"):
                    dfrom = FetchSource[meta["degraded_from"]]
                if dfrom is not None and meta.get("source"):
                    mgr.scheduler.record_degrade(
                        self.worker_id, key, FetchSource[meta["source"]],
                        mgr.now, degraded_from=dfrom)
            fail_key = "<build-failed>" if op == "fetch" \
                else "<transfer-failed>"
            acts = mgr.scheduler.on_fetch_done(
                self.worker_id, key if ok else fail_key, mgr.now)
            mgr._dispatch(acts)
            mgr._cond.notify_all()

    def _h_snapshot(self, meta: Dict, payload: bytes):
        mgr = self._mgr
        # fold the status FIRST: records/sources are node-side
        # DELTAS — discarding a reply (stale token) must not
        # drop them
        self.library.update(meta.get("status"), mgr)
        info = self._pop(meta["token"])
        if info is None:
            return
        _, recipe, plan, receiver_id = info
        if meta.get("ok") and payload:
            # forward the blob; the receiver decodes on ITS thread/process
            mgr._deliver_install_wire(receiver_id, recipe, bytes(payload),
                                      plan)
        else:
            mgr._deliver_install(receiver_id, recipe, None, plan,
                                 degraded_from=FetchSource.PEER)

    def _h_template(self, meta: Dict, payload: bytes):
        mgr = self._mgr
        sid = meta["sid"]
        with mgr._lock:
            sf = mgr._stripes.get(sid)
        if sf is None or sf.done:
            return
        blob = bytes(payload)
        try:
            if isinstance(sf.buffer, _RemoteStripeTracker):
                spec_tree, tmeta = pcm_wire.decode_template_specs(blob)
                plan = ChunkPlan(spec_tree,
                                 chunk_bytes=tmeta["chunk_bytes"])
                mgr._stripe_template(sid, plan, None, None,
                                     tmeta["nbytes"],
                                     tmeta["build_seconds"],
                                     tmeta["aot_seconds"], wire_blob=blob)
            else:
                dec = pcm_wire.decode_template(blob)
                plan = ChunkPlan(dec["spec_tree"],
                                 chunk_bytes=dec["chunk_bytes"])
                mgr._stripe_template(sid, plan, dec["clone"],
                                     dec["host_halves"], dec["nbytes"],
                                     dec["build_seconds"],
                                     dec["aot_seconds"])
        except BaseException:
            traceback.print_exc(file=sys.stderr)
            mgr._stripe_failed(sid)

    def _h_donor_chunk(self, meta: Dict, payload: bytes):
        ref = ChunkRef(meta["ref"][0], *map(int, meta["ref"][1:]))
        arr = np.frombuffer(bytes(payload),
                            dtype=np.dtype(meta["dtype"]))
        arr = arr.reshape(meta["shape"])
        self._mgr._stripe_deliver(meta["sid"], ref, arr, meta["sha"],
                                  meta["lane"])

    def _h_lane_drained(self, meta: Dict, payload: bytes):
        mgr = self._mgr
        with mgr._lock:
            sf = mgr._stripes.get(meta["sid"])
            if meta.get("sent"):
                mgr.planner.observe_stage("d2h", int(meta["sent"]),
                                          float(meta["seconds"]))
        if sf is not None:
            sf.buffer.add_lane_seconds(meta["lane"],
                                       float(meta["seconds"]))

    def _h_stripe_lane_lost(self, meta: Dict, payload: bytes):
        mgr = self._mgr
        sid, lane = meta["sid"], meta["lane"]
        delivered = meta.get("delivered")
        with mgr._cond:
            sf = mgr._stripes.get(sid)
            if sf is not None and delivered is not None \
                    and isinstance(sf.buffer, _RemoteStripeTracker):
                # the NODE's verified set is authoritative: frames queued
                # toward a dead lane must be re-forwarded
                sf.buffer.reconcile(tuple(d) for d in delivered)
                sf.buffer.install_posted = False
            if meta.get("corrupt"):
                mgr._stripe_stats["lane_failures"] += 1
        mgr._stripe_lane_lost(sid, lane)

    def _h_stripe_done(self, meta: Dict, payload: bytes):
        mgr = self._mgr
        self.library.update(meta.get("status"), mgr)
        sid = meta["sid"]
        ok = bool(meta.get("ok"))
        with mgr._cond:
            sf = mgr._stripes.pop(sid, None)
            if sf is None:
                return
            sf.done = True
            mgr._cancel_remote_lanes(sf)
            mgr._flow_done_locked(sf.plan,
                                  measured_seconds=meta.get("measured"),
                                  failed=not ok)
            if not self.alive:
                mgr._cond.notify_all()
                return
            acts = mgr.scheduler.on_fetch_done(
                self.worker_id,
                meta.get("key") if ok else "<transfer-failed>", mgr.now)
            mgr._dispatch(acts)
            mgr._cond.notify_all()

    def _h_ack(self, meta: Dict, payload: bytes):
        mgr = self._mgr
        # fold the status FIRST: records/sources are node-side
        # DELTAS — discarding a reply (stale token) must not
        # drop them
        self.library.update(meta.get("status"), mgr)
        info = self._pop(meta["token"])
        if info is None:
            return
        _, event, errors, recipe = info
        if meta.get("ok"):
            with mgr._lock:
                if self.alive:
                    self.store.admit_recipe(recipe, mgr.mode.persist_tier,
                                            now=mgr.now)
        else:
            errors.append(RuntimeError(
                meta.get("error")
                or f"warm-up failed on remote worker {self.worker_id}"))
        event.set()

    def _h_demoted(self, meta: Dict, payload: bytes):
        mgr = self._mgr
        # fold the status FIRST: records/sources are node-side
        # DELTAS — discarding a reply (stale token) must not
        # drop them
        self.library.update(meta.get("status"), mgr)
        info = self._pop(meta["token"])
        if info is None:
            return
        _, event, demoted, key, tier = info
        snap = None
        if meta.get("has") and payload:
            try:
                snap = pcm_wire.decode_snapshot(payload)
            except BaseException:
                traceback.print_exc(file=sys.stderr)
        if snap is not None:
            mgr.snapshots.put(snap)
            if tier == Tier.LOCAL_DISK:
                mgr.snapshots.spill(key)
            with mgr._lock:
                demoted.append(self.worker_id)
                self.store.drop(key, down_to=tier)
                try:
                    self.store.admit(key, tier, snap.nbytes, now=mgr.now)
                except TierFullError:
                    pass
        event.set()

    def _h_demoted_ctx(self, meta: Dict, payload: bytes):
        # retirement demotion: the node ships each device-resident context
        # back; it lands in the manager's node pool exactly where a local
        # worker's retirement demotion would have put it
        try:
            self._mgr.snapshots.put(pcm_wire.decode_snapshot(payload))
        except BaseException:
            traceback.print_exc(file=sys.stderr)

    def _h_bye(self, meta: Dict, payload: bytes):
        self.library.update(meta.get("status"), self._mgr)
        self._finalize()

    # --------------------------------------------------------- lifecycle ---
    def _finalize(self):
        mgr = self._mgr
        with mgr._cond:
            first = not self._finalized
            self._finalized = True
            if first and not self.library.absorbed:
                self.library.absorbed = True
                mgr._absorb_library(self.library)
            mgr._cond.notify_all()
        self._closed_evt.set()
        if self.conn is not None:
            self.conn.close()
        if mgr._router is not None:
            mgr._router.unregister(self.worker_id)


class PCMManager:
    concurrent = True        # work progresses on threads, not via step()

    def __init__(self, mode: ContextMode = ContextMode.FULL,
                 n_workers: int = 2,
                 planner: Optional[TransferPlanner] = None,
                 snapshots: Optional[SnapshotPool] = None,
                 spill_dir: Optional[str] = None,
                 p2p: bool = True,
                 donor_wait: bool = True,
                 streamed: bool = True,
                 stripe_width: Optional[int] = None,
                 export_chunk_budget: int = 4,
                 chunk_bytes: int = 64 << 20):
        self.mode = mode
        # streamed=True (default): PEER fetches stripe verified chunks
        # across multiple donors with non-blocking budgeted donor exports,
        # and DISK promotions stream spill entries to device; False keeps
        # the monolithic export/restore path (the measured baseline)
        self.streamed = streamed
        self.export_chunk_budget = int(export_chunk_budget)
        self.chunk_bytes = int(chunk_bytes)
        self.planner = planner or TransferPlanner()
        sched_kwargs = {} if stripe_width is None \
            else {"stripe_width": stripe_width}
        self.scheduler = ContextAwareScheduler(mode=mode, planner=self.planner,
                                               p2p=p2p, donor_wait=donor_wait,
                                               **sched_kwargs)
        self.snapshots = snapshots or SnapshotPool(spill_dir=spill_dir,
                                                   chunk_bytes=chunk_bytes)
        # the POOL/DISK rungs of the scheduler's FetchSource ladder read
        # node-pool residency straight from the live SnapshotPool
        self.scheduler.pool_tier = self.snapshots.tier
        # when a pooled snapshot is consumed (restored elsewhere) or lost
        # (capacity), the HOST_RAM residency other workers recorded for it
        # is a phantom — invalidate it so the placement ladder stays honest
        self.snapshots.set_on_gone(self._on_snapshot_gone)
        self.workers: Dict[str, LiveWorker] = {}
        self._futures: Dict[str, Future] = {}
        self._ids = itertools.count()
        self._task_ids = itertools.count()
        # in-flight striped PEER transfers, by stripe id
        self._stripes: Dict[int, _StripeFetch] = {}
        self._stripe_ids = itertools.count()
        self._stripe_stats = {"stripes": 0, "chunks": 0,
                              "lane_failures": 0, "degrades": 0}
        # test hook: callable(stripe_id, ref, lane) -> bool; True corrupts
        # that chunk's digest in transit (exercises the degrade paths)
        self._chunk_fault = None
        self._pinned: set = set()
        self._lock = threading.RLock()
        self._cond = threading.Condition(self._lock)
        self._t0 = time.monotonic()
        # counters of departed workers (preempted/stopped), folded into
        # stats() so churn doesn't erase history
        self._retired = {"cold": 0, "warm": 0, "build_seconds": 0.0,
                         "restore_seconds": 0.0, "builder_calls": 0,
                         "restores": 0, "demotions": 0,
                         "peer_installs": 0, "peer_exports": 0,
                         "peer_install_seconds": 0.0}
        # every worker ever spawned (incl. preempted ones): shutdown joins
        # them all so no thread is mid-JAX-call at interpreter teardown
        self._spawned: List[LiveWorker] = []
        # multi-host: socket transport (armed by listen()); loopback
        # in-process workers remain the default and never touch these
        self._listener: Optional[Listener] = None
        self._router: Optional[Router] = None
        self._hb = 1.0
        atexit.register(_shutdown_at_exit, weakref.ref(self))
        for _ in range(n_workers):
            self.add_worker()

    # ------------------------------------------------------------- clock ----
    @property
    def now(self) -> float:
        """THE clock for scheduler events on this backend: monotonic
        seconds since the manager started (the simulator backend's ``now``
        is its modeled event-loop time — same contract)."""
        return time.monotonic() - self._t0

    # ------------------------------------------------------------- pool ----
    def add_worker(self, worker_id: Optional[str] = None,
                   profile=None) -> str:
        """Spawn one worker actor. ``worker_id``/``profile`` let a
        WorkerFactory-driven elastic pool attach the trace's worker
        identity and DeviceProfile (heterogeneous HBM capacity + profile-
        aware placement); both default to manager-generated/anonymous."""
        with self._cond:
            wid = worker_id or f"live{next(self._ids):03d}"
            if wid in self.workers:
                raise ValueError(f"worker {wid!r} already exists")
            w = LiveWorker(wid, self, profile=profile)
            w.store.pinned.update(self._pinned)
            w.library.pinned.update(self._pinned)
            self.workers[wid] = w
            self._spawned.append(w)
            w.start()
            acts = self.scheduler.on_worker_join(wid, self.now,
                                                 profile=profile,
                                                 store=w.store)
            self._dispatch(acts)
            self._cond.notify_all()
            return wid

    def preempt_worker(self, worker_id: str):
        """No-warning device reclaim. The scheduler requeues the worker's
        in-flight task immediately; the worker thread finishes whatever
        invocation it cannot abandon, discards the result, then retires —
        demoting every device-resident context (pins included: they cannot
        survive losing the device) into the node snapshot pool, where a
        rejoining worker restores it at transfer cost."""
        with self._cond:
            w = self.workers.pop(worker_id, None)
            if w is not None:
                w.alive = False
            acts = self.scheduler.on_worker_leave(worker_id, self.now)
            self._fail_unresolved()
            self._dispatch(acts)
            self._cond.notify_all()
        if w is not None:
            w.post((_RETIRE,))

    # --------------------------------------------------------- multi-host --
    def listen(self, host: str = "127.0.0.1", port: int = 0,
               heartbeat: float = 1.0,
               lost_after: float = 10.0) -> Tuple[str, int]:
        """Open the socket transport: node processes that connect to the
        returned ``(host, port)`` join the pool as :class:`RemoteWorker`s
        (``transport_kind="socket"`` in the scheduler, so the planner
        prices their lanes from NIC calibration, not memcpy history).
        Loss detection is two-layered — socket EOF fires instantly, the
        heartbeat monitor declares a silent peer lost after ``lost_after``
        seconds — and both feed the normal preemption path."""
        if self._listener is not None:
            return self._listener.address
        self._hb = float(heartbeat)
        self._router = Router(lost_after=lost_after)
        self._listener = Listener(host, port, self._on_node_connect)
        return self._listener.address

    @property
    def address(self) -> Optional[Tuple[str, int]]:
        return None if self._listener is None else self._listener.address

    def _on_node_connect(self, sock, addr):
        """Accept-thread half of a node join: read the HELLO synchronously
        (worker identity + DeviceProfile), reply with the runtime config
        the node must mirror (eviction mode, chunking, pins), then hand
        the socket to a framed Connection and register the RemoteWorker
        under the same join path as an in-process worker."""
        from repro.core.transport import read_frame, write_frame
        kind, meta, payload = read_frame(sock)
        if kind != "hello":
            raise TransportError(
                f"expected hello from {addr}, got {kind!r}")
        wid = meta["worker_id"]
        profile = pickle.loads(payload) if payload else None
        write_frame(sock, "hello_ack", {
            "mode": self.mode.value, "streamed": self.streamed,
            "chunk_bytes": self.chunk_bytes,
            "export_chunk_budget": self.export_chunk_budget,
            "pinned": sorted(self._pinned)})
        w = RemoteWorker(wid, self, profile=profile)
        conn = Connection(
            sock, f"node-{wid}", on_frame=w._on_frame,
            on_lost=lambda _c, reason: self._remote_lost(w, reason),
            heartbeat=self._hb)
        w.conn = conn
        with self._cond:
            if wid in self.workers:
                conn.close()
                raise ValueError(f"worker {wid!r} already exists")
            w.store.pinned.update(self._pinned)
            w.library.pinned.update(self._pinned)
            self.workers[wid] = w
            self._spawned.append(w)
            self._router.register(wid, conn)
            conn.start()
            acts = self.scheduler.on_worker_join(
                wid, self.now, profile=profile, store=w.store,
                transport_kind="socket")
            self._dispatch(acts)
            self._cond.notify_all()

    def wait_for_workers(self, worker_ids: List[str],
                         timeout: float = 30.0):
        """Block until every named worker has joined (node processes
        register asynchronously when their HELLO lands)."""
        deadline = time.monotonic() + timeout
        with self._cond:
            while not all(wid in self.workers for wid in worker_ids):
                remaining = deadline - time.monotonic()
                if remaining <= 0:
                    missing = [wid for wid in worker_ids
                               if wid not in self.workers]
                    raise TimeoutError(
                        f"workers {missing} did not join within "
                        f"{timeout:.1f}s")
                self._cond.wait(remaining)

    def _remote_lost(self, w: "RemoteWorker", reason: str):
        """A remote worker's link died — EOF (killed process) or heartbeat
        timeout (declared lost). Runs the exact preemption path a local
        no-warning reclaim runs, plus transport cleanup: fail the flows
        and synchronous waits parked on the connection, fail over every
        stripe lane the node was serving, and requeue its in-flight task."""
        with self._cond:
            known = self.workers.get(w.worker_id) is w
            if known:
                self.workers.pop(w.worker_id, None)
            was_alive = w.alive
            w.alive = False
            # stripes this node was RECEIVING cannot conclude
            for sid, sf in list(self._stripes.items()):
                if sf.receiver_id == w.worker_id:
                    self._stripe_failed_locked(sid)
            # pending request/reply exchanges: flows fail, waiters release
            with w._plock:
                pending, w._pending = dict(w._pending), {}
            for info in pending.values():
                tag = info[0]
                if tag in ("fetch", "install"):
                    self._flow_done_locked(info[2], failed=True)
                elif tag == "donate":
                    self._deliver_install(info[3], info[1], None, info[2],
                                          degraded_from=FetchSource.PEER)
                elif tag == "warm":
                    info[2].append(RuntimeError(
                        f"remote worker {w.worker_id} lost during "
                        f"warm-up: {reason}"))
                    info[1].set()
                elif tag == "demote":
                    info[1].set()
            # stripes this node was DONATING to: lane failover (surviving
            # donors re-export only the undelivered refs)
            for sid, sf in list(self._stripes.items()):
                for lane, did in enumerate(sf.donor_ids):
                    if did == w.worker_id and lane not in sf.failed_lanes:
                        self._stripe_lane_lost(sid, lane)
            if known and was_alive:
                acts = self.scheduler.on_worker_leave(w.worker_id,
                                                      self.now)
                self._fail_unresolved()
                self._dispatch(acts)
            self._cond.notify_all()
        w._finalize()

    def shutdown(self, timeout: Optional[float] = None):
        """Stop all worker threads and join every thread this manager ever
        spawned — including retired (preempted) ones that may still be
        finishing a demotion or an AOT compile. Joins indefinitely by
        default: every runtime-internal message terminates (a compile just
        takes seconds), and a thread left alive inside a JAX call at
        interpreter exit aborts the process during XLA teardown. Pass a
        ``timeout`` to bound the join when user task functions may block.
        Idempotent; also runs via atexit."""
        with self._cond:
            live, self.workers = list(self.workers.values()), {}
            spawned, self._spawned = list(self._spawned), []
            for w in live:
                w.alive = False
            # nothing will run the remaining work: fail its futures now so
            # waiters error immediately instead of sleeping out a deadline
            for fut in self._futures.values():
                if not fut.done:
                    fut.set_exception(RuntimeError(
                        f"backend shut down with task {fut.task_id} "
                        "unresolved"))
            self._cond.notify_all()
        for w in live:
            w.post((_STOP,))
        for w in spawned:
            w.join(timeout)
        if self._router is not None:
            self._router.close()
        if self._listener is not None:
            self._listener.close()
        self._router = self._listener = None

    # ------------------------------------------------------------ submit ---
    def submit(self, fn: Callable, args: tuple = (), kwargs: dict = None,
               recipe: Optional[ContextRecipe] = None,
               recipes: Optional[Mapping[str, ContextRecipe]] = None,
               n_items: int = 1, priority: int = 0) -> Future:
        """Submit one task. ``recipe=None`` (and no ``recipes``) is an
        explicitly contextless task — the scheduler treats it as warm on
        every worker. ``recipes`` maps context names to recipes for
        multi-context tasks."""
        named: Dict[str, ContextRecipe] = dict(recipes or {})
        if recipe is not None and not named:
            named = {recipe.name: recipe}
        with self._cond:
            task_id = f"t{next(self._task_ids):05d}"
            task = Task(task_id=task_id, recipes=tuple(named.values()),
                        context_names=tuple(named.keys()), n_items=n_items,
                        priority=priority, payload=(fn, args, kwargs or {}))
            fut = Future(task_id, self)
            self._futures[task_id] = fut
            acts = self.scheduler.submit(task, self.now)
            self._dispatch(acts)
            return fut

    # ----------------------------------------------------------- contexts --
    def warm_up(self, recipe: ContextRecipe,
                worker_ids: Optional[List[str]] = None) -> List[str]:
        """Materialize ``recipe`` on the given (default: all) workers now,
        off the task critical path. Synchronous: returns once every worker
        has the context resident; a failing builder re-raises here."""
        pending: List[tuple] = []
        errors: List[BaseException] = []
        with self._lock:
            for wid in list(worker_ids or self.workers):
                w = self.workers.get(wid)
                if w is None or not w.alive:
                    continue
                ev = threading.Event()
                w.post(("warm", recipe, ev, errors))
                pending.append((wid, ev))
        for _, ev in pending:
            ev.wait()
        if errors:
            raise errors[0]
        return [wid for wid, _ in pending]

    def demote_context(self, recipe: ContextRecipe,
                       tier: Tier = Tier.HOST_RAM,
                       worker_ids: Optional[List[str]] = None) -> List[str]:
        """Physically demote the context off the device on the given
        (default: all) workers: DEVICE -> HOST_RAM snapshot in the node
        pool, spilled on to LOCAL_DISK when ``tier=Tier.LOCAL_DISK``.
        Synchronous; returns the workers that held (and demoted) it."""
        if tier not in (Tier.HOST_RAM, Tier.LOCAL_DISK):
            raise ValueError(f"demotion target must be HOST_RAM or "
                             f"LOCAL_DISK, got {tier!r}")
        key = recipe.key()
        pending: List[threading.Event] = []
        demoted: List[str] = []
        with self._lock:
            for wid in list(worker_ids or self.workers):
                w = self.workers.get(wid)
                if w is None or not w.alive or not w.library.has(key):
                    continue
                ev = threading.Event()
                w.post(("demote", key, tier, ev, demoted))
                pending.append(ev)
        for ev in pending:
            ev.wait()
        return demoted   # pinned contexts refuse demotion and are omitted

    def pin_context(self, recipe: ContextRecipe):
        """Exempt the context from mode-driven eviction on every current
        and future worker."""
        with self._lock:
            key = recipe.key()
            self._pinned.add(key)
            for w in self.workers.values():
                w.store.pin(key)
                w.library.pin(key)

    def release_context(self, recipe: ContextRecipe):
        with self._lock:
            key = recipe.key()
            self._pinned.discard(key)
            for w in self.workers.values():
                w.store.unpin(key)
                w.library.unpin(key)

    def residency(self, recipe: ContextRecipe) -> Dict[str, Tier]:
        """Highest tier at which each worker currently holds the context."""
        with self._lock:
            key = recipe.key()
            return {wid: w.store.highest_tier(key)
                    for wid, w in self.workers.items()}

    def snapshot_tier(self, recipe: ContextRecipe) -> Optional[Tier]:
        """Tier of the node-pool snapshot for this context (HOST_RAM or
        LOCAL_DISK), or None when no demoted copy exists."""
        t = self.snapshots.tier(recipe.key())
        return None if t is None else Tier(t)

    def fetch_history(self, recipe: Optional[ContextRecipe] = None) -> List:
        """FetchSource-ladder decisions made so far (optionally filtered
        to one recipe) — (worker, key, source, donor, t) records from the
        scheduler's ``fetch_log``."""
        with self._lock:
            return self.scheduler.fetch_history(recipe)

    def _on_snapshot_gone(self, key: str):
        """Pool callback (fired outside the pool lock): the snapshot for
        ``key`` no longer exists, so HOST_RAM/LOCAL_DISK residency claims
        by workers that do not actually hold the materialized context are
        phantoms — clear them or the placement ladder keeps routing tasks
        to a worker that would cold-rebuild."""
        with self._lock:
            for w in self.workers.values():
                if not w.library.has(key):
                    w.store.invalidate(key, Tier.HOST_RAM)
                    w.store.invalidate(key, Tier.LOCAL_DISK)

    # --------------------------------------------------------- execution ---
    def _dispatch(self, actions: List[Action]):
        """Route scheduler actions to worker mailboxes (callers hold the
        lock). A PEER fetch goes to the DONOR first (("donate", ...) —
        export then ship to the receiver); every other fetch source runs
        on the receiver's own thread down the Library ladder. ``cancel``
        needs no message: the revalidation barrier in ``_handle_start``
        discards any stale in-flight copy."""
        for a in actions:
            w = self.workers.get(a.worker_id)
            if w is None or not w.alive:
                if a.kind == "start":
                    acts = self.scheduler.on_worker_leave(a.worker_id,
                                                          self.now)
                    self._fail_unresolved()
                    self._dispatch(acts)
                elif a.kind == "fetch":
                    self._flow_done_locked(a.plan)
                continue
            if a.kind == "start":
                w.post(("start", a.task_id))
            elif a.kind == "fetch":
                if a.source == FetchSource.PEER and a.donor:
                    lanes = []
                    for did in (a.donors or (a.donor,)):
                        dw = self.workers.get(did)
                        if dw is not None and dw.alive and did not in lanes:
                            lanes.append(did)
                    if lanes and self.streamed:
                        self._start_stripe(a, lanes)
                        continue
                    if lanes:
                        self.workers[lanes[0]].post(
                            ("donate", a.recipe, a.worker_id, a.plan))
                        continue
                w.post(("fetch", a.recipe, a.plan))

    # ---------------------------------------------------------- striping ---
    def _start_stripe(self, a: Action, lanes: List[str]):
        """Launch a striped PEER fetch (callers hold the lock): one
        ``donate_chunks`` lane per live donor from the planner's committed
        stripe set, plus — once the template lands — a receiver-side pool
        lane for the immutable params when the node pool holds a copy."""
        sid = next(self._stripe_ids)
        n_pool = 1 if self.snapshots.tier(a.recipe.key()) is not None else 0
        sf = _StripeFetch(sid, a.recipe, a.worker_id, a.plan,
                          tuple(lanes), n_pool)
        receiver = self.workers.get(a.worker_id)
        if isinstance(receiver, RemoteWorker):
            # the real StripeBuffer runs in the node process; the manager
            # tracks + forwards (one _stripe_deliver codepath either way)
            sf.buffer = _RemoteStripeTracker(self, sid, receiver)
        self._stripes[sid] = sf
        self._stripe_stats["stripes"] += 1
        for lane, did in enumerate(lanes):
            self.workers[did].post(
                ("donate_chunks", sid, a.recipe, a.worker_id,
                 {"lane": lane, "n_donor": len(lanes), "n_pool": n_pool,
                  "with_template": lane == 0, "ref_ids": None,
                  "cursor": 0}))

    def _stripe_template(self, stripe_id: int, plan, clone, host_halves,
                         nbytes: int, build_seconds: float,
                         aot_seconds: float, device_tree=None,
                         wire_blob: Optional[bytes] = None):
        """Primary-lane template metadata arrived: arm the buffer's
        expected-ref set and activate the pool lane (it needs the plan).
        For a REMOTE receiver the tracker forwards the template over the
        wire — verbatim when it already arrived as a blob (remote donor),
        wire-encoded on the writer thread otherwise (``device_tree`` is
        the local donor's device half, reduced to specs)."""
        with self._cond:
            sf = self._stripes.get(stripe_id)
            if sf is None or sf.done:
                return
            if isinstance(sf.buffer, _RemoteStripeTracker):
                sf.buffer.set_template_remote(
                    plan, sf.recipe, self.chunk_bytes, clone, host_halves,
                    nbytes, build_seconds, aot_seconds,
                    device_tree=device_tree, wire_blob=wire_blob)
            else:
                sf.buffer.set_template(plan, clone, host_halves, nbytes,
                                       build_seconds, aot_seconds)
            if sf.n_pool:
                pool_lane = len(sf.donor_ids)
                sf.lane_owner[pool_lane] = pool_lane
                w = self.workers.get(sf.receiver_id)
                if isinstance(w, RemoteWorker) and w.alive:
                    # the pool lives manager-side: serve its refs from a
                    # helper thread, forwarding through the tracker
                    threading.Thread(
                        target=self._remote_pool_lane,
                        args=(stripe_id, sf.recipe,
                              {"lane": pool_lane,
                               "n_donor": len(sf.donor_ids),
                               "n_pool": sf.n_pool}),
                        name=f"pcm-pool-lane-{stripe_id}",
                        daemon=True).start()
                elif w is not None and w.alive:
                    w.post(("stripe_pool", stripe_id, sf.recipe,
                            {"lane": pool_lane,
                             "n_donor": len(sf.donor_ids),
                             "n_pool": sf.n_pool}))
        self._maybe_install_stripe(stripe_id)

    def _remote_pool_lane(self, stripe_id: int, recipe: ContextRecipe,
                          spec: dict):
        """Pool stripe lane for a REMOTE receiver: the node SnapshotPool
        is manager-side state, so the manager itself reads the immutable
        params chunks (HOST_RAM slices or verified spill entries) and
        forwards them through the stripe tracker. Mirrors the receiver-
        thread ``_handle_stripe_pool``; any failure loses this lane only."""
        lane = spec["lane"]
        with self._lock:
            sf = self._stripes.get(stripe_id)
        if sf is None or sf.done:
            return
        t0 = time.monotonic()
        try:
            plan = sf.buffer.plan
            refs = sf.buffer.missing_refs(
                assign_lanes(plan.refs, spec["n_donor"],
                             spec["n_pool"])[lane])
            if not refs:
                return
            snap = self.snapshots.peek(recipe.key())
            if snap is None:
                raise LookupError(
                    f"pool snapshot for {recipe.key()} gone before the "
                    "stripe lane could read it")
            if snap.spilled:
                needed = {r.key for r in refs}
                flat = dict(self.snapshots.spill_store().iter_entries(
                    snap.spill_key, keys=needed))
            else:
                flat = ChunkPlan.flat_map(
                    {name: {"params": comp["params"]}
                     for name, comp in snap.host_state.items()
                     if isinstance(comp, dict) and "params" in comp})
            self.snapshots.stripe_reads += len(refs)
            for ref in refs:
                piece = np.asarray(plan.extract(flat, ref))
                if not self._stripe_deliver(stripe_id, ref, piece,
                                            chunk_digest(piece), lane):
                    return
        except BaseException:
            traceback.print_exc(file=sys.stderr)
            self._stripe_lane_lost(stripe_id, lane)
        finally:
            sf.buffer.add_lane_seconds(lane, time.monotonic() - t0)

    def _stripe_deliver(self, stripe_id: int, ref, piece, sha: str,
                        lane: int) -> bool:
        """Verify-and-buffer one chunk from a lane thread. Returns False
        when the lane should stop exporting (corruption failed the lane,
        or the stripe concluded elsewhere)."""
        with self._lock:
            sf = self._stripes.get(stripe_id)
            fault = self._chunk_fault
        if sf is None or sf.done:
            return False
        if fault is not None and fault(stripe_id, ref, lane):
            sha = "0" * 64              # test hook: corrupt in transit
        try:
            sf.buffer.deliver(ref, piece, sha, lane=lane)
        except ChunkCorruptionError:
            traceback.print_exc(file=sys.stderr)
            with self._lock:
                self._stripe_stats["lane_failures"] += 1
            self._stripe_lane_lost(stripe_id, lane)
            return False
        with self._lock:
            self._stripe_stats["chunks"] += 1
        self._maybe_install_stripe(stripe_id)
        return True

    def _maybe_install_stripe(self, stripe_id: int):
        with self._cond:
            sf = self._stripes.get(stripe_id)
            if sf is None or sf.done or sf.buffer.install_posted \
                    or not sf.buffer.complete():
                return
            sf.buffer.install_posted = True
            w = self.workers.get(sf.receiver_id)
            if w is None or not w.alive:
                self._stripe_failed_locked(stripe_id)
                return
            w.post(("install_stripe", stripe_id))

    def _stripe_lane_lost(self, stripe_id: int, phys_lane: int):
        """A physical stripe lane died — corrupt chunk, donor preempted or
        evicted, pool snapshot consumed. Reassign every assignment lane it
        owned to a surviving donor lane (only the UNDELIVERED refs are
        re-exported; the fetch never restarts), or — with no survivors —
        degrade the receiver down the normal fetch ladder."""
        with self._cond:
            sf = self._stripes.get(stripe_id)
            if sf is None or sf.done or phys_lane in sf.failed_lanes:
                return
            sf.failed_lanes.add(phys_lane)
            lost = [al for al, owner in sf.lane_owner.items()
                    if owner == phys_lane]
            if not lost:
                return
            n_donor = len(sf.donor_ids)
            survivors = []
            for lane in range(n_donor):
                if lane in sf.failed_lanes:
                    continue
                dw = self.workers.get(sf.donor_ids[lane])
                if dw is not None and dw.alive:
                    survivors.append(lane)
            plan = sf.buffer.plan
            if survivors:
                sl = survivors[0]
                donor = self.workers[sf.donor_ids[sl]]
                for al in lost:
                    sf.lane_owner[al] = sl
                    spec = {"lane": al, "via_lane": sl, "n_donor": n_donor,
                            "n_pool": sf.n_pool,
                            "with_template": plan is None and al == 0,
                            "ref_ids": None, "cursor": 0}
                    if plan is not None:
                        assigned = assign_lanes(plan.refs, n_donor,
                                                sf.n_pool)[al]
                        spec["ref_ids"] = frozenset(
                            r.id for r in sf.buffer.missing_refs(assigned))
                    donor.post(("donate_chunks", stripe_id, sf.recipe,
                                sf.receiver_id, spec))
                return
            # every donor lane gone: fall down the ladder without
            # restarting — the receiver's Library resolves POOL/DISK/FS/
            # BUILD and the degrade is logged against the PEER promise
            sf.done = True
            self._stripes.pop(stripe_id, None)
            self._stripe_stats["degrades"] += 1
            self._cancel_remote_lanes(sf)
            self._flow_done_locked(sf.plan, failed=True)
            w = self.workers.get(sf.receiver_id)
            if w is not None and w.alive:
                w.post(("install", sf.recipe, None, None,
                        FetchSource.PEER))
            self._cond.notify_all()

    def _stripe_failed_locked(self, stripe_id: int):
        """The stripe cannot conclude (receiver gone): drop it and free
        its planner flows as failed (callers hold the lock)."""
        sf = self._stripes.pop(stripe_id, None)
        if sf is None:
            return
        sf.done = True
        self._cancel_remote_lanes(sf)
        self._flow_done_locked(sf.plan, failed=True)
        self._cond.notify_all()

    def _stripe_failed(self, stripe_id: int):
        with self._cond:
            self._stripe_failed_locked(stripe_id)

    def _cancel_remote_lanes(self, sf: _StripeFetch):
        """Tell remote DONORS a concluded stripe needs no more chunks —
        local donors notice via ``_stripe_deliver`` returning False, but
        a node keeps exporting until told (callers hold the lock; send is
        just an enqueue)."""
        for did in set(sf.donor_ids):
            dw = self.workers.get(did)
            if isinstance(dw, RemoteWorker) and dw.alive:
                dw._send("stripe_cancel", {"sid": sf.stripe_id})

    # ---------------------------------------------------------- transfers --
    def _deliver_install(self, receiver_id: str, recipe: ContextRecipe,
                         snap, plan: Optional[TransferPlan],
                         degraded_from: Optional[FetchSource] = None):
        """Hand a donated snapshot (or a None fallback) to the receiving
        worker's mailbox; called from donor threads and drain paths. The
        post happens under the manager lock: preemption flips ``alive``
        and enqueues the retirement under the same lock, so the install
        either lands ahead of the retirement (drained with its flow freed)
        or is rerouted here — never stranded in a dead mailbox."""
        with self._cond:
            w = self.workers.get(receiver_id)
            if w is None or not w.alive:
                # receiver departed mid-transfer: the scheduler already
                # cleaned it up — just free the planner flow
                self._flow_done_locked(plan, failed=True)
                self._cond.notify_all()
                return
            w.post(("install", recipe, snap, plan, degraded_from))

    def _deliver_install_wire(self, receiver_id: str,
                              recipe: ContextRecipe, blob: bytes,
                              plan: Optional[TransferPlan],
                              degraded_from: Optional[FetchSource] = None):
        """Same contract as ``_deliver_install`` but the snapshot is still
        WIRE bytes (a remote donor's export): a local receiver decodes it
        on its own thread; a remote receiver gets the blob forwarded
        verbatim — the manager never materializes the arrays."""
        with self._cond:
            w = self.workers.get(receiver_id)
            if w is None or not w.alive:
                self._flow_done_locked(plan, failed=True)
                self._cond.notify_all()
                return
            w.post(("install_wire", recipe, blob, plan, degraded_from))

    def _flow_done(self, plan: Optional[TransferPlan],
                   measured_seconds: Optional[float] = None,
                   failed: bool = False):
        with self._lock:
            self._flow_done_locked(plan, measured_seconds, failed=failed)

    def _flow_done_locked(self, plan: Optional[TransferPlan],
                          measured_seconds: Optional[float] = None,
                          failed: bool = False):
        """Report a planned transfer finished: frees the donor/FS slots
        immediately and, when real transfer work was measured (peer
        export + restore), feeds it into the planner's bandwidth
        calibration. Failed transfers are recorded as such — never
        calibrated, never left as phantom in-flight flows (callers hold
        the lock)."""
        if plan is not None:
            self.planner.complete(plan, self.now,
                                  measured_seconds=measured_seconds,
                                  failed=failed)

    def _fail_unresolved(self):
        """Surface scheduler-declared failures (max_attempts exceeded) as
        Future exceptions; callers hold the lock."""
        for task in self.scheduler.failed:
            fut = self._futures.get(task.duplicates_of or task.task_id)
            if fut is not None and not fut.done:
                fut.set_exception(RuntimeError(fut._lost_message()))

    def wait(self, fut: Future, timeout: Optional[float] = None):
        """Block until ``fut`` resolves. Purely event-driven: futures are
        resolved (and workers joined/preempted) under ``self._cond`` with
        a ``notify_all``, so this waits on that condition and re-checks
        only when the runtime actually changed. Raises TimeoutError on
        deadline; RuntimeError when the future can no longer resolve
        (pool drained, or stalled with no live workers and no timeout)."""
        deadline = None if timeout is None else time.monotonic() + timeout
        with self._cond:
            while not fut.done:
                if self.outstanding == 0:
                    raise RuntimeError(fut._lost_message())
                if not self.workers and deadline is None:
                    raise RuntimeError(
                        f"backend stalled with {self.outstanding} task(s) "
                        f"outstanding and no live workers while waiting on "
                        f"{fut.task_id} — add workers or pass "
                        "result(timeout=...)")
                if deadline is None:
                    self._cond.wait()
                else:
                    remaining = deadline - time.monotonic()
                    if remaining <= 0:
                        raise TimeoutError(
                            f"task {fut.task_id} did not complete within "
                            f"{timeout:.3f}s ({self.outstanding} tasks "
                            "still outstanding)")
                    self._cond.wait(remaining)

    def step(self) -> bool:
        """Protocol compatibility for pollers: the concurrent runtime makes
        progress on worker threads, so ``step`` just waits briefly for
        activity. False once nothing is outstanding."""
        with self._cond:
            if self.outstanding == 0:
                return False
            self._cond.wait(0.01)
            return True

    def run_until_idle(self, timeout: Optional[float] = None) -> int:
        """Block until no tasks are queued or running (or the pool has no
        live workers to run them). Returns completions observed while
        draining."""
        deadline = None if timeout is None else time.monotonic() + timeout
        with self._cond:
            start = len(self.scheduler.completions)
            while self.outstanding and self.workers:
                if deadline is not None and time.monotonic() >= deadline:
                    break
                self._cond.wait(0.05)
            return len(self.scheduler.completions) - start

    # ------------------------------------------------------------- status ---
    @property
    def outstanding(self) -> int:
        return self.scheduler.outstanding

    def lookup_task(self, task_id: str) -> Optional[Task]:
        return self.scheduler.tasks.get(task_id)

    def _absorb_library(self, library: Library):
        """Fold a departing worker's Library counters into the manager
        totals (called from the worker thread at retirement/stop)."""
        with self._lock:
            r = self._retired
            for rec in library.records:
                r["cold" if rec.cold else "warm"] += 1
            r["build_seconds"] += library.build_seconds_total
            r["restore_seconds"] += library.restore_seconds_total
            r["builder_calls"] += library.builder_calls
            r["restores"] += library.restores
            r["demotions"] += library.demotions
            r["peer_installs"] += library.peer_installs
            r["peer_exports"] += library.peer_exports
            r["peer_install_seconds"] += library.peer_install_seconds

    # ------------------------------------------------------------- stats ---
    def stats(self) -> Dict:
        with self._lock:
            cold, warm = self._retired["cold"], self._retired["warm"]
            build_s = self._retired["build_seconds"]
            restore_s = self._retired["restore_seconds"]
            builder_calls = self._retired["builder_calls"]
            restores = self._retired["restores"]
            demotions = self._retired["demotions"]
            peer_installs = self._retired["peer_installs"]
            peer_exports = self._retired["peer_exports"]
            peer_install_s = self._retired["peer_install_seconds"]
            for w in self.workers.values():
                for rec in w.library.records:
                    cold += rec.cold
                    warm += not rec.cold
                build_s += w.library.build_seconds_total
                restore_s += w.library.restore_seconds_total
                builder_calls += w.library.builder_calls
                restores += w.library.restores
                demotions += w.library.demotions
                peer_installs += w.library.peer_installs
                peer_exports += w.library.peer_exports
                peer_install_s += w.library.peer_install_seconds
            return {"cold_invocations": cold, "warm_invocations": warm,
                    "context_build_seconds": build_s,
                    "context_restore_seconds": restore_s,
                    "builder_calls": builder_calls,
                    "context_restores": restores,
                    "context_demotions": demotions,
                    "peer_installs": peer_installs,
                    "peer_exports": peer_exports,
                    "peer_install_seconds": peer_install_s,
                    "completed": len(self.scheduler.completions),
                    "snapshot_pool": self.snapshots.stats(),
                    "striping": dict(self._stripe_stats),
                    "transfer": self.planner.stats(self.now)}
