"""PCMManager — the live concurrent (in-process) PCM runtime.

Actor-style execution core. Each logical worker is a **thread with a
mailbox** (:class:`LiveWorker`) that owns its :class:`Library` and
:class:`ContextStore`: builds, invocations, demotions and restores for a
worker all happen on its own thread, serialized by the mailbox. The
manager side — the ContextAwareScheduler, the Future table and the task
clock — lives behind one lock; every runtime event (submit, fetch-done,
task-done, join, leave) enters through that lock, asks the scheduler for
Actions, and routes them to worker mailboxes. Nothing busy-polls:
Futures carry condition variables and resolve the moment a worker reports
completion.

Context tier movement is PHYSICAL here. Preempting a worker
(``preempt_worker``) reclaims its device: the scheduler instantly requeues
its in-flight task (no-warning semantics), and the worker's retirement
demotes every device-resident context into the node
:class:`~repro.core.store.SnapshotPool` — params and engine state pulled
to host RAM via ``jax.device_get``, AOT-executable handles retained, LRU
snapshots spilling to local disk through ``checkpoint/io``. A later
``add_worker`` (or any worker that needs the context) PROMOTES the
snapshot instead of re-running the builder: zero builder calls, zero XLA
compiles, bit-identical decode state — the paper's restore-cost-not-
startup-cost claim, executed for real.

All scheduler event timestamps come from one clock source: ``self.now``
(monotonic seconds since the manager started). The simulator backend uses
its event-loop clock the same way, so durations and completions are
comparable across backends.

PCMManager implements the ``ExecutionBackend`` protocol
(:mod:`repro.core.backend`): the PCMClient session API drives it
interchangeably with the simulator-backed dry-run backend.
"""

from __future__ import annotations

import atexit
import itertools
import queue
import sys
import threading
import time
import traceback
import weakref
from typing import Any, Callable, Dict, List, Mapping, Optional

import numpy as np

from repro.core.context import (GB, ContextRecipe, ContextSnapshot,
                                export_context, restore_context,
                                stripe_export_state, stripe_export_template)
from repro.core.library import Library
from repro.core.scheduler import (Action, ContextAwareScheduler, ContextMode,
                                  Task)
from repro.core.store import (ContextStore, SnapshotPool, Tier,
                              TierFullError)
from repro.core.streaming import (ChunkCorruptionError, ChunkPlan,
                                  StripeBuffer, assign_lanes, chunk_digest)
from repro.core.transfer import FetchSource, TransferPlan, TransferPlanner


class Future:
    """Handle to one submitted task.

    Resolution is event-driven: worker threads (live backend) or the
    discrete-event loop (simulator backend) call ``set_result`` /
    ``set_exception``; ``result(timeout=...)`` blocks on a condition
    variable (live) or drives the event loop (sim) via ``backend.wait``.
    """

    def __init__(self, task_id: str, backend):
        self.task_id = task_id
        self._backend = backend
        self._value: Any = None
        self._ready = False
        self.error: Optional[BaseException] = None
        self._callbacks: List[Callable[["Future"], None]] = []
        self._cond = threading.Condition(threading.RLock())

    # ------------------------------------------------------- resolution ----
    def set_result(self, value: Any):
        with self._cond:
            if self._ready:
                return
            self._value = value
            self._ready = True
            self._cond.notify_all()
            self._fire_callbacks()

    def set_exception(self, error: BaseException):
        with self._cond:
            if self._ready:
                return
            self.error = error
            self._ready = True
            self._cond.notify_all()
            self._fire_callbacks()

    def _fire_callbacks(self):
        # fired from the resolving thread (a worker actor, holding runtime
        # locks): a raising user callback must never wedge the runtime
        callbacks, self._callbacks = self._callbacks, []
        for cb in callbacks:
            try:
                cb(self)
            except BaseException:
                traceback.print_exc(file=sys.stderr)

    def add_done_callback(self, cb: Callable[["Future"], None]):
        """Run ``cb(self)`` once the future resolves (immediately if it
        already has)."""
        with self._cond:
            if not self._ready:
                self._callbacks.append(cb)
                return
        cb(self)

    # --------------------------------------------------------- consumers ---
    def result(self, timeout: Optional[float] = None) -> Any:
        if not self._ready:
            self._backend.wait(self, timeout)
        if self.error is not None:
            raise self.error
        return self._value

    def _lost_message(self) -> str:
        task = self._backend.lookup_task(self.task_id)
        if task is None:
            return f"task {self.task_id} lost (unknown to the scheduler)"
        where = task.last_worker or "<never placed>"
        return (f"task {self.task_id} lost after {task.attempts} attempt(s); "
                f"last worker {where} — exceeded max_attempts or the pool "
                "drained with the task unfinished")

    @property
    def done(self) -> bool:
        return self._ready


_STOP = "stop"
_RETIRE = "retire"


class _StripeFetch:
    """Bookkeeping for one in-flight striped PEER transfer: which physical
    lanes exist (donor workers, plus an optional receiver-side pool lane),
    which lane currently OWNS each assignment lane's refs (ownership moves
    when a lane dies), and the receiver-side :class:`StripeBuffer` that
    verifies and assembles the chunks."""

    def __init__(self, stripe_id: int, recipe: ContextRecipe,
                 receiver_id: str, plan: Optional[TransferPlan],
                 donor_ids: tuple, n_pool: int):
        self.stripe_id = stripe_id
        self.recipe = recipe
        self.receiver_id = receiver_id
        self.plan = plan                  # planner TransferPlan (the flows)
        self.donor_ids = donor_ids        # assignment lane -> donor worker
        self.n_pool = n_pool
        self.buffer = StripeBuffer()
        self.failed_lanes: set = set()    # physical lanes that died
        # assignment lane -> physical lane responsible for its refs
        self.lane_owner: Dict[int, int] = {
            lane: lane for lane in range(len(donor_ids))}
        self.done = False


def _shutdown_at_exit(mgr_ref):
    """Join every worker thread before the interpreter (and the XLA
    runtime underneath it) tears down — a thread still inside a JAX call
    at exit aborts the process with 'terminate called without an active
    exception'."""
    mgr = mgr_ref()
    if mgr is not None:
        mgr.shutdown()


class LiveWorker:
    """One worker actor: a daemon thread + mailbox owning this worker's
    Library (materialized contexts) and ContextStore (residency
    bookkeeping).

    Mailbox messages are ``(kind, ...)`` tuples routed by the manager:

      ("start", task_id)              run one task invocation
      ("fetch", recipe, plan)         materialize/restore off-path (the
                                      POOL/DISK/FS/BUILD ladder rungs)
      ("donate", recipe, rcv, plan)   export this worker's warm context as
                                      a template snapshot and ship it to
                                      receiver ``rcv`` (monolithic PEER
                                      transfer — the donor keeps its copy
                                      serving)
      ("donate_chunks", sid, recipe,  streamed PEER: export a budget of
       rcv, spec)                     verified chunks of stripe ``sid``
                                      this turn, then repost the
                                      continuation to our own tail so
                                      queued serving work interleaves
      ("stripe_pool", sid, recipe,    serve immutable params chunks out of
       spec)                          the node SnapshotPool as an extra
                                      stripe lane (runs on the receiver)
      ("install_stripe", sid)         assemble stripe ``sid``'s chunks and
                                      promote the result (adopt)
      ("install", recipe, snap, plan  adopt a donated snapshot (restore to
       [, degraded_from])             device); ``snap=None`` degrades to
                                      the normal fetch ladder (logged as a
                                      degrade when ``degraded_from`` set)
      ("warm", recipe, event)         synchronous warm-up (event set when
                                      resident)
      ("demote", key, tier, event)    physically demote one context
      ("retire",)                     device reclaimed: demote everything
                                      to the node snapshot pool and exit
      ("stop",)                       plain shutdown (no demotion)

    The thread executes messages strictly in order, so a preemption that
    lands mid-invocation simply marks the worker dead (``alive=False``):
    the in-flight result is discarded at the revalidation barrier and the
    retirement demotion runs right after the current message finishes —
    no state is ever snapshotted mid-mutation.
    """

    def __init__(self, worker_id: str, manager: "PCMManager", profile=None):
        self.worker_id = worker_id
        self.profile = profile          # cluster.devices.DeviceProfile
        self.library = Library(worker_id, snapshots=manager.snapshots,
                               streamed=manager.streamed)
        hbm_gb = getattr(profile, "hbm_gb", None)
        self.store = ContextStore(device_bytes=int(hbm_gb * GB)) \
            if hbm_gb else ContextStore()
        self.mailbox: "queue.SimpleQueue" = queue.SimpleQueue()
        self.alive = True
        self._mgr = manager
        self._thread = threading.Thread(
            target=self._run, name=f"pcm-worker-{worker_id}", daemon=True)

    def start(self):
        self._thread.start()

    def post(self, msg: tuple):
        self.mailbox.put(msg)

    def join(self, timeout: Optional[float] = None):
        self._thread.join(timeout)

    # ------------------------------------------------------------ thread ---
    def _run(self):
        while True:
            msg = self.mailbox.get()
            kind = msg[0]
            if kind == _STOP:
                self._mgr._absorb_library(self.library)
                break
            if kind == _RETIRE:
                try:
                    self.library.demote_all(force=True)
                except BaseException:
                    traceback.print_exc(file=sys.stderr)
                self._mgr._absorb_library(self.library)
                break
            try:
                if kind == "start":
                    self._handle_start(msg[1])
                elif kind == "fetch":
                    self._handle_fetch(msg[1], msg[2])
                elif kind == "donate":
                    self._handle_donate(msg[1], msg[2], msg[3])
                elif kind == "donate_chunks":
                    self._handle_donate_chunks(msg[1], msg[2], msg[3],
                                               msg[4])
                elif kind == "stripe_pool":
                    self._handle_stripe_pool(msg[1], msg[2], msg[3])
                elif kind == "install_stripe":
                    self._handle_install_stripe(msg[1])
                elif kind == "install":
                    self._handle_install(msg[1], msg[2], msg[3],
                                         msg[4] if len(msg) > 4 else None)
                elif kind == "warm":
                    self._handle_warm(msg[1], msg[2], msg[3])
                elif kind == "demote":
                    self._handle_demote(msg[1], msg[2], msg[3], msg[4])
            except BaseException:
                traceback.print_exc(file=sys.stderr)
        self._drain_events()

    def _drain_events(self):
        # a retiring worker must not strand synchronous callers or wedge
        # the transfer pipeline: release every event still waiting in the
        # mailbox, degrade pending donations so their receivers fall back
        # down the ladder, and free every planner flow we would have
        # completed
        while True:
            try:
                msg = self.mailbox.get_nowait()
            except queue.Empty:
                return
            kind = msg[0]
            if kind == "donate":
                # the receiver is still FETCHING on our donation: hand it
                # a None snapshot so it degrades to pool/disk/builder
                self._mgr._deliver_install(msg[2], msg[1], None, msg[3],
                                           degraded_from=FetchSource.PEER)
            elif kind == "donate_chunks":
                self._mgr._stripe_lane_lost(
                    msg[1], msg[4].get("via_lane", msg[4]["lane"]))
            elif kind == "stripe_pool":
                self._mgr._stripe_lane_lost(msg[1], msg[3]["lane"])
            elif kind == "install_stripe":
                self._mgr._stripe_failed(msg[1])
            elif kind == "fetch":
                self._mgr._flow_done(msg[2], failed=True)
            elif kind == "install":
                self._mgr._flow_done(msg[3], failed=True)
            for part in msg:
                if isinstance(part, threading.Event):
                    part.set()

    # ---------------------------------------------------------- handlers ---
    def _handle_start(self, task_id: str):
        mgr = self._mgr
        with mgr._lock:
            entry = mgr.scheduler.running.get(task_id)
            if not self.alive or entry is None or entry[0] != self.worker_id:
                return                    # cancelled / reassigned / dead
            task = mgr.scheduler.tasks[task_id]
            fn, args, kwargs = task.payload
            named = dict(zip(task.context_names, task.recipes))
        # the invocation (context build/restore + user fn) runs OUTSIDE the
        # manager lock: other workers keep dispatching and completing
        value: Any = None
        error: Optional[BaseException] = None
        try:
            value = self.library.invoke(fn, args, kwargs,
                                        recipes=named or None,
                                        task_id=task_id)
        except BaseException as e:       # report, don't wedge the pool
            error = e
        with mgr._cond:
            self._drain_stage_obs_locked()
            entry = mgr.scheduler.running.get(task_id)
            if not self.alive or entry is None or entry[0] != self.worker_id:
                # preempted or cancelled while running: the scheduler has
                # already requeued/completed elsewhere — discard this copy
                return
            if mgr.mode == ContextMode.AGNOSTIC:
                self.library.evict_all()
            elif mgr.mode == ContextMode.PARTIAL:
                for key in task.keys():
                    self.library.evict(key)
            fut = mgr._futures.get(task.duplicates_of or task_id)
            if fut is not None:
                if error is None:
                    fut.set_result(value)
                else:
                    fut.set_exception(error)
            acts = mgr.scheduler.on_task_done(self.worker_id, task_id,
                                              mgr.now)
            mgr._fail_unresolved()
            mgr._dispatch(acts)
            mgr._cond.notify_all()

    def _handle_fetch(self, recipe: ContextRecipe,
                      plan: Optional[TransferPlan]):
        mgr = self._mgr
        if not self.alive:
            mgr._flow_done(plan, failed=True)
            return           # preempted with the fetch still queued: the
            # scheduler already forgot this worker — don't burn a build
        key = recipe.key()
        failed = False
        try:
            self.library.ensure(recipe)
        except BaseException:
            traceback.print_exc(file=sys.stderr)
            failed = True
        with mgr._cond:
            # no bandwidth calibration here: the ladder fallback may have
            # run the builder, which says nothing about a transfer rate
            mgr._flow_done_locked(plan, failed=failed)
            self._drain_stage_obs_locked()
            if not self.alive:
                return
            # a failed build reports a non-matching key: the scheduler
            # clears the fetching state without recording residency
            acts = mgr.scheduler.on_fetch_done(
                self.worker_id, "<build-failed>" if failed else key, mgr.now)
            mgr._dispatch(acts)
            mgr._cond.notify_all()

    def _handle_donate(self, recipe: ContextRecipe, receiver_id: str,
                       plan: Optional[TransferPlan]):
        """Donor side of a PEER transfer: export a template snapshot of
        the warm context (non-destructive — this worker keeps serving from
        its own copy) and ship it to the receiver's mailbox. A donor that
        lost the context (race with eviction/preemption) or whose export
        fails degrades the receiver to the normal fetch ladder."""
        mgr = self._mgr
        key = recipe.key()
        snap = None
        if self.alive and self.library.has(key):
            try:
                snap = export_context(self.library.context(key))
                self.library.peer_exports += 1
            except BaseException:
                traceback.print_exc(file=sys.stderr)
        mgr._deliver_install(receiver_id, recipe, snap, plan,
                             degraded_from=None if snap is not None
                             else FetchSource.PEER)

    def _export_budget(self) -> Optional[int]:
        """Chunks this donor may export in ONE mailbox turn, tied to its
        queue depth: an idle donor drains its lane in one go (None = no
        cap); a donor with queued serving work exports fewer chunks per
        turn the deeper its mailbox, so decode latency under fanout stays
        bounded by a few chunk ``device_get``s."""
        depth = self.mailbox.qsize()
        if depth <= 0:
            return None
        return max(1, self._mgr.export_chunk_budget // (1 + depth))

    def _drain_stage_obs_locked(self):
        """Feed per-stage (disk/h2d) timings observed by this worker's
        streamed restores into the planner's pipeline calibration (callers
        hold the manager lock)."""
        obs, self.library.stage_observations = \
            self.library.stage_observations, []
        for stage, nbytes, seconds in obs:
            self._mgr.planner.observe_stage(stage, nbytes, seconds)

    def _handle_donate_chunks(self, stripe_id: int, recipe: ContextRecipe,
                              receiver_id: str, spec: dict):
        """Donor lane of a STREAMED peer transfer: recompute the
        deterministic ChunkPlan over this context's device half (plans
        depend on template shapes alone, so every donor and the manager
        agree with zero coordination), export up to a budget of chunks
        this turn — each a per-chunk ``device_get`` + sha256 — then repost
        the continuation to our own mailbox TAIL so serving work queued
        behind this message runs between export turns. The primary lane
        additionally ships the template metadata (structural clone sharing
        our AOT executables + synthesized host halves) before its first
        chunk."""
        mgr = self._mgr
        key = recipe.key()
        lane = spec["lane"]                      # assignment lane
        via = spec.get("via_lane", lane)         # physical lane doing work
        with mgr._lock:
            sf = mgr._stripes.get(stripe_id)
        if sf is None or sf.done:
            return                               # stripe already concluded
        if not (self.alive and self.library.has(key)):
            mgr._stripe_lane_lost(stripe_id, via)
            return
        t0 = time.monotonic()
        sent = 0
        try:
            ctx = self.library.context(key)
            device = stripe_export_state(ctx)
            plan = ChunkPlan(device, chunk_bytes=mgr.chunk_bytes)
            if spec.get("with_template"):
                clone, host_halves, host_nbytes = stripe_export_template(ctx)
                self.library.peer_exports += 1
                mgr._stripe_template(stripe_id, plan, clone, host_halves,
                                     host_nbytes + plan.total_bytes,
                                     ctx.build_seconds, ctx.aot_seconds)
                spec = dict(spec, with_template=False)
            if spec.get("ref_ids") is not None:
                refs = [r for r in plan.refs if r.id in spec["ref_ids"]]
            else:
                refs = assign_lanes(plan.refs, spec["n_donor"],
                                    spec["n_pool"])[lane]
            cursor = spec.get("cursor", 0)
            budget = self._export_budget()
            stop = len(refs) if budget is None \
                else min(len(refs), cursor + budget)
            flat = ChunkPlan.flat_map(device)
            while cursor < stop:
                ref = refs[cursor]
                # np.asarray of the device-array slice IS the per-chunk
                # device_get — the only point this turn touches the device
                piece = np.asarray(plan.extract(flat, ref))
                sent += int(piece.nbytes)
                if not mgr._stripe_deliver(stripe_id, ref, piece,
                                           chunk_digest(piece), via):
                    return               # lane failed or stripe concluded
                cursor += 1
        except BaseException:
            traceback.print_exc(file=sys.stderr)
            mgr._stripe_lane_lost(stripe_id, via)
            return
        finally:
            elapsed = time.monotonic() - t0
            sf.buffer.add_lane_seconds(via, elapsed)
            if sent:
                with mgr._lock:
                    mgr.planner.observe_stage("d2h", sent, elapsed)
        if cursor < len(refs):
            self.post(("donate_chunks", stripe_id, recipe, receiver_id,
                       dict(spec, cursor=cursor)))
        # else: lane drained — the install fires from the last delivery

    def _handle_stripe_pool(self, stripe_id: int, recipe: ContextRecipe,
                            spec: dict):
        """Receiver-side pool lane of a striped fetch: serve the immutable
        ``params`` chunks straight out of the node SnapshotPool — HOST_RAM
        slices, or per-entry verified reads of a spilled snapshot — while
        donor lanes carry the rest. Activated only after the template
        lands (the plan must exist). Any failure loses this lane only: its
        refs reassign to a surviving donor lane."""
        mgr = self._mgr
        lane = spec["lane"]
        with mgr._lock:
            sf = mgr._stripes.get(stripe_id)
        if sf is None or sf.done:
            return
        if not self.alive:
            mgr._stripe_lane_lost(stripe_id, lane)
            return
        t0 = time.monotonic()
        try:
            plan = sf.buffer.plan
            refs = sf.buffer.missing_refs(
                assign_lanes(plan.refs, spec["n_donor"],
                             spec["n_pool"])[lane])
            if not refs:
                return
            snap = mgr.snapshots.peek(recipe.key())
            if snap is None:
                raise LookupError(
                    f"pool snapshot for {recipe.key()} gone before the "
                    "stripe lane could read it")
            if snap.spilled:
                needed = {r.key for r in refs}
                flat = dict(mgr.snapshots.spill_store().iter_entries(
                    snap.spill_key, keys=needed))
            else:
                flat = ChunkPlan.flat_map(
                    {name: {"params": comp["params"]}
                     for name, comp in snap.host_state.items()
                     if isinstance(comp, dict) and "params" in comp})
            mgr.snapshots.stripe_reads += len(refs)
            for ref in refs:
                piece = np.asarray(plan.extract(flat, ref))
                if not mgr._stripe_deliver(stripe_id, ref, piece,
                                           chunk_digest(piece), lane):
                    return
        except BaseException:
            traceback.print_exc(file=sys.stderr)
            mgr._stripe_lane_lost(stripe_id, lane)
        finally:
            sf.buffer.add_lane_seconds(lane, time.monotonic() - t0)

    def _handle_install_stripe(self, stripe_id: int):
        """Receiver end of a striped transfer: assemble the verified
        chunks into a template snapshot and promote it (adopt — zero
        builder calls, zero compiles, exactly like the monolithic PEER
        install)."""
        mgr = self._mgr
        with mgr._lock:
            sf = mgr._stripes.get(stripe_id)
        if sf is None:
            return
        if not self.alive:
            mgr._stripe_failed(stripe_id)
            return
        key = sf.recipe.key()
        failed = False
        measured = None
        try:
            buf = sf.buffer
            host_state = buf.assemble()
            snap = ContextSnapshot(
                recipe=sf.recipe, value=buf.clone, host_state=host_state,
                nbytes=buf.nbytes, build_seconds=buf.build_seconds,
                aot_seconds=buf.aot_seconds,
                demote_seconds=buf.export_seconds)
            ctx = restore_context(snap, self.worker_id)
            self.library.adopt(ctx)
            # same calibration contract as the monolithic install: export
            # work (slowest lane) + restore work, never queue wait
            measured = snap.demote_seconds + ctx.restore_seconds
        except BaseException:
            traceback.print_exc(file=sys.stderr)
            failed = True
            measured = None
        with mgr._cond:
            mgr._stripes.pop(stripe_id, None)
            sf.done = True
            mgr._flow_done_locked(sf.plan, measured_seconds=measured,
                                  failed=failed)
            self._drain_stage_obs_locked()
            if not self.alive:
                return
            acts = mgr.scheduler.on_fetch_done(
                self.worker_id, "<transfer-failed>" if failed else key,
                mgr.now)
            mgr._dispatch(acts)
            mgr._cond.notify_all()

    def _handle_install(self, recipe: ContextRecipe, snap,
                        plan: Optional[TransferPlan],
                        degraded_from: Optional[FetchSource] = None):
        """Receiver side of a PEER transfer: promote the donated snapshot
        to the device and adopt it (zero builder calls, zero compiles).
        ``snap=None`` means the donor could not serve — fall back down the
        ladder (pool -> disk -> builder) via ``Library.ensure``, recorded
        in the scheduler's fetch_log as a degrade from ``degraded_from``
        when set."""
        mgr = self._mgr
        if not self.alive:
            mgr._flow_done(plan, failed=True)
            return
        key = recipe.key()
        failed = False
        measured = None
        try:
            if snap is not None:
                ctx = restore_context(snap, self.worker_id)
                self.library.adopt(ctx)
                # calibrate on the transfer WORK (donor export + receiver
                # restore), not end-to-end latency: mailbox queue wait —
                # or a builder run on a degraded donation — is not
                # bandwidth
                measured = snap.demote_seconds + ctx.restore_seconds
            else:
                self.library.ensure(recipe)
        except BaseException:
            traceback.print_exc(file=sys.stderr)
            failed = True
            measured = None
        with mgr._cond:
            mgr._flow_done_locked(plan, measured_seconds=measured,
                                  failed=failed)
            self._drain_stage_obs_locked()
            if not self.alive:
                return
            if snap is None and not failed and degraded_from is not None:
                # the ladder fallback actually acquired the context — log
                # where it landed so fetch_history stays a complete account
                mgr.scheduler.record_degrade(
                    self.worker_id, key, self.library.fetch_sources[-1],
                    mgr.now, degraded_from=degraded_from)
            acts = mgr.scheduler.on_fetch_done(
                self.worker_id, "<transfer-failed>" if failed else key,
                mgr.now)
            mgr._dispatch(acts)
            mgr._cond.notify_all()

    def _handle_warm(self, recipe: ContextRecipe, event: threading.Event,
                     errors: List[BaseException]):
        mgr = self._mgr
        try:
            self.library.ensure(recipe)
            with mgr._lock:
                if self.alive:
                    self.store.admit_recipe(recipe, mgr.mode.persist_tier,
                                            now=mgr.now)
        except BaseException as e:       # surfaced by warm_up in the caller
            errors.append(e)
        finally:
            event.set()

    def _handle_demote(self, key: str, tier: Tier, event: threading.Event,
                       demoted: List[str]):
        mgr = self._mgr
        try:
            snap = self.library.demote(key)   # None when absent or pinned
            if snap is not None and tier == Tier.LOCAL_DISK:
                mgr.snapshots.spill(key)
            with mgr._lock:
                if snap is not None:
                    demoted.append(self.worker_id)
                    self.store.drop(key, down_to=tier)
                    try:
                        self.store.admit(key, tier, snap.nbytes,
                                         now=mgr.now)
                    except TierFullError:
                        # bookkeeping refused (pin-blocked tier); the
                        # snapshot is in the pool regardless — the worker
                        # just shows as cold to the placement ladder.
                        # Other ValueErrors are admission bugs: propagate.
                        pass
        finally:
            event.set()


class PCMManager:
    concurrent = True        # work progresses on threads, not via step()

    def __init__(self, mode: ContextMode = ContextMode.FULL,
                 n_workers: int = 2,
                 planner: Optional[TransferPlanner] = None,
                 snapshots: Optional[SnapshotPool] = None,
                 spill_dir: Optional[str] = None,
                 p2p: bool = True,
                 donor_wait: bool = True,
                 streamed: bool = True,
                 stripe_width: Optional[int] = None,
                 export_chunk_budget: int = 4,
                 chunk_bytes: int = 64 << 20):
        self.mode = mode
        # streamed=True (default): PEER fetches stripe verified chunks
        # across multiple donors with non-blocking budgeted donor exports,
        # and DISK promotions stream spill entries to device; False keeps
        # the monolithic export/restore path (the measured baseline)
        self.streamed = streamed
        self.export_chunk_budget = int(export_chunk_budget)
        self.chunk_bytes = int(chunk_bytes)
        self.planner = planner or TransferPlanner()
        sched_kwargs = {} if stripe_width is None \
            else {"stripe_width": stripe_width}
        self.scheduler = ContextAwareScheduler(mode=mode, planner=self.planner,
                                               p2p=p2p, donor_wait=donor_wait,
                                               **sched_kwargs)
        self.snapshots = snapshots or SnapshotPool(spill_dir=spill_dir,
                                                   chunk_bytes=chunk_bytes)
        # the POOL/DISK rungs of the scheduler's FetchSource ladder read
        # node-pool residency straight from the live SnapshotPool
        self.scheduler.pool_tier = self.snapshots.tier
        # when a pooled snapshot is consumed (restored elsewhere) or lost
        # (capacity), the HOST_RAM residency other workers recorded for it
        # is a phantom — invalidate it so the placement ladder stays honest
        self.snapshots.set_on_gone(self._on_snapshot_gone)
        self.workers: Dict[str, LiveWorker] = {}
        self._futures: Dict[str, Future] = {}
        self._ids = itertools.count()
        self._task_ids = itertools.count()
        # in-flight striped PEER transfers, by stripe id
        self._stripes: Dict[int, _StripeFetch] = {}
        self._stripe_ids = itertools.count()
        self._stripe_stats = {"stripes": 0, "chunks": 0,
                              "lane_failures": 0, "degrades": 0}
        # test hook: callable(stripe_id, ref, lane) -> bool; True corrupts
        # that chunk's digest in transit (exercises the degrade paths)
        self._chunk_fault = None
        self._pinned: set = set()
        self._lock = threading.RLock()
        self._cond = threading.Condition(self._lock)
        self._t0 = time.monotonic()
        # counters of departed workers (preempted/stopped), folded into
        # stats() so churn doesn't erase history
        self._retired = {"cold": 0, "warm": 0, "build_seconds": 0.0,
                         "restore_seconds": 0.0, "builder_calls": 0,
                         "restores": 0, "demotions": 0,
                         "peer_installs": 0, "peer_exports": 0,
                         "peer_install_seconds": 0.0}
        # every worker ever spawned (incl. preempted ones): shutdown joins
        # them all so no thread is mid-JAX-call at interpreter teardown
        self._spawned: List[LiveWorker] = []
        atexit.register(_shutdown_at_exit, weakref.ref(self))
        for _ in range(n_workers):
            self.add_worker()

    # ------------------------------------------------------------- clock ----
    @property
    def now(self) -> float:
        """THE clock for scheduler events on this backend: monotonic
        seconds since the manager started (the simulator backend's ``now``
        is its modeled event-loop time — same contract)."""
        return time.monotonic() - self._t0

    # ------------------------------------------------------------- pool ----
    def add_worker(self, worker_id: Optional[str] = None,
                   profile=None) -> str:
        """Spawn one worker actor. ``worker_id``/``profile`` let a
        WorkerFactory-driven elastic pool attach the trace's worker
        identity and DeviceProfile (heterogeneous HBM capacity + profile-
        aware placement); both default to manager-generated/anonymous."""
        with self._cond:
            wid = worker_id or f"live{next(self._ids):03d}"
            if wid in self.workers:
                raise ValueError(f"worker {wid!r} already exists")
            w = LiveWorker(wid, self, profile=profile)
            w.store.pinned.update(self._pinned)
            w.library.pinned.update(self._pinned)
            self.workers[wid] = w
            self._spawned.append(w)
            w.start()
            acts = self.scheduler.on_worker_join(wid, self.now,
                                                 profile=profile,
                                                 store=w.store)
            self._dispatch(acts)
            self._cond.notify_all()
            return wid

    def preempt_worker(self, worker_id: str):
        """No-warning device reclaim. The scheduler requeues the worker's
        in-flight task immediately; the worker thread finishes whatever
        invocation it cannot abandon, discards the result, then retires —
        demoting every device-resident context (pins included: they cannot
        survive losing the device) into the node snapshot pool, where a
        rejoining worker restores it at transfer cost."""
        with self._cond:
            w = self.workers.pop(worker_id, None)
            if w is not None:
                w.alive = False
            acts = self.scheduler.on_worker_leave(worker_id, self.now)
            self._fail_unresolved()
            self._dispatch(acts)
            self._cond.notify_all()
        if w is not None:
            w.post((_RETIRE,))

    def shutdown(self, timeout: Optional[float] = None):
        """Stop all worker threads and join every thread this manager ever
        spawned — including retired (preempted) ones that may still be
        finishing a demotion or an AOT compile. Joins indefinitely by
        default: every runtime-internal message terminates (a compile just
        takes seconds), and a thread left alive inside a JAX call at
        interpreter exit aborts the process during XLA teardown. Pass a
        ``timeout`` to bound the join when user task functions may block.
        Idempotent; also runs via atexit."""
        with self._cond:
            live, self.workers = list(self.workers.values()), {}
            spawned, self._spawned = list(self._spawned), []
            for w in live:
                w.alive = False
            # nothing will run the remaining work: fail its futures now so
            # waiters error immediately instead of sleeping out a deadline
            for fut in self._futures.values():
                if not fut.done:
                    fut.set_exception(RuntimeError(
                        f"backend shut down with task {fut.task_id} "
                        "unresolved"))
            self._cond.notify_all()
        for w in live:
            w.post((_STOP,))
        for w in spawned:
            w.join(timeout)

    # ------------------------------------------------------------ submit ---
    def submit(self, fn: Callable, args: tuple = (), kwargs: dict = None,
               recipe: Optional[ContextRecipe] = None,
               recipes: Optional[Mapping[str, ContextRecipe]] = None,
               n_items: int = 1, priority: int = 0) -> Future:
        """Submit one task. ``recipe=None`` (and no ``recipes``) is an
        explicitly contextless task — the scheduler treats it as warm on
        every worker. ``recipes`` maps context names to recipes for
        multi-context tasks."""
        named: Dict[str, ContextRecipe] = dict(recipes or {})
        if recipe is not None and not named:
            named = {recipe.name: recipe}
        with self._cond:
            task_id = f"t{next(self._task_ids):05d}"
            task = Task(task_id=task_id, recipes=tuple(named.values()),
                        context_names=tuple(named.keys()), n_items=n_items,
                        priority=priority, payload=(fn, args, kwargs or {}))
            fut = Future(task_id, self)
            self._futures[task_id] = fut
            acts = self.scheduler.submit(task, self.now)
            self._dispatch(acts)
            return fut

    # ----------------------------------------------------------- contexts --
    def warm_up(self, recipe: ContextRecipe,
                worker_ids: Optional[List[str]] = None) -> List[str]:
        """Materialize ``recipe`` on the given (default: all) workers now,
        off the task critical path. Synchronous: returns once every worker
        has the context resident; a failing builder re-raises here."""
        pending: List[tuple] = []
        errors: List[BaseException] = []
        with self._lock:
            for wid in list(worker_ids or self.workers):
                w = self.workers.get(wid)
                if w is None or not w.alive:
                    continue
                ev = threading.Event()
                w.post(("warm", recipe, ev, errors))
                pending.append((wid, ev))
        for _, ev in pending:
            ev.wait()
        if errors:
            raise errors[0]
        return [wid for wid, _ in pending]

    def demote_context(self, recipe: ContextRecipe,
                       tier: Tier = Tier.HOST_RAM,
                       worker_ids: Optional[List[str]] = None) -> List[str]:
        """Physically demote the context off the device on the given
        (default: all) workers: DEVICE -> HOST_RAM snapshot in the node
        pool, spilled on to LOCAL_DISK when ``tier=Tier.LOCAL_DISK``.
        Synchronous; returns the workers that held (and demoted) it."""
        if tier not in (Tier.HOST_RAM, Tier.LOCAL_DISK):
            raise ValueError(f"demotion target must be HOST_RAM or "
                             f"LOCAL_DISK, got {tier!r}")
        key = recipe.key()
        pending: List[threading.Event] = []
        demoted: List[str] = []
        with self._lock:
            for wid in list(worker_ids or self.workers):
                w = self.workers.get(wid)
                if w is None or not w.alive or not w.library.has(key):
                    continue
                ev = threading.Event()
                w.post(("demote", key, tier, ev, demoted))
                pending.append(ev)
        for ev in pending:
            ev.wait()
        return demoted   # pinned contexts refuse demotion and are omitted

    def pin_context(self, recipe: ContextRecipe):
        """Exempt the context from mode-driven eviction on every current
        and future worker."""
        with self._lock:
            key = recipe.key()
            self._pinned.add(key)
            for w in self.workers.values():
                w.store.pin(key)
                w.library.pin(key)

    def release_context(self, recipe: ContextRecipe):
        with self._lock:
            key = recipe.key()
            self._pinned.discard(key)
            for w in self.workers.values():
                w.store.unpin(key)
                w.library.unpin(key)

    def residency(self, recipe: ContextRecipe) -> Dict[str, Tier]:
        """Highest tier at which each worker currently holds the context."""
        with self._lock:
            key = recipe.key()
            return {wid: w.store.highest_tier(key)
                    for wid, w in self.workers.items()}

    def snapshot_tier(self, recipe: ContextRecipe) -> Optional[Tier]:
        """Tier of the node-pool snapshot for this context (HOST_RAM or
        LOCAL_DISK), or None when no demoted copy exists."""
        t = self.snapshots.tier(recipe.key())
        return None if t is None else Tier(t)

    def fetch_history(self, recipe: Optional[ContextRecipe] = None) -> List:
        """FetchSource-ladder decisions made so far (optionally filtered
        to one recipe) — (worker, key, source, donor, t) records from the
        scheduler's ``fetch_log``."""
        with self._lock:
            return self.scheduler.fetch_history(recipe)

    def _on_snapshot_gone(self, key: str):
        """Pool callback (fired outside the pool lock): the snapshot for
        ``key`` no longer exists, so HOST_RAM/LOCAL_DISK residency claims
        by workers that do not actually hold the materialized context are
        phantoms — clear them or the placement ladder keeps routing tasks
        to a worker that would cold-rebuild."""
        with self._lock:
            for w in self.workers.values():
                if not w.library.has(key):
                    w.store.invalidate(key, Tier.HOST_RAM)
                    w.store.invalidate(key, Tier.LOCAL_DISK)

    # --------------------------------------------------------- execution ---
    def _dispatch(self, actions: List[Action]):
        """Route scheduler actions to worker mailboxes (callers hold the
        lock). A PEER fetch goes to the DONOR first (("donate", ...) —
        export then ship to the receiver); every other fetch source runs
        on the receiver's own thread down the Library ladder. ``cancel``
        needs no message: the revalidation barrier in ``_handle_start``
        discards any stale in-flight copy."""
        for a in actions:
            w = self.workers.get(a.worker_id)
            if w is None or not w.alive:
                if a.kind == "start":
                    acts = self.scheduler.on_worker_leave(a.worker_id,
                                                          self.now)
                    self._fail_unresolved()
                    self._dispatch(acts)
                elif a.kind == "fetch":
                    self._flow_done_locked(a.plan)
                continue
            if a.kind == "start":
                w.post(("start", a.task_id))
            elif a.kind == "fetch":
                if a.source == FetchSource.PEER and a.donor:
                    lanes = []
                    for did in (a.donors or (a.donor,)):
                        dw = self.workers.get(did)
                        if dw is not None and dw.alive and did not in lanes:
                            lanes.append(did)
                    if lanes and self.streamed:
                        self._start_stripe(a, lanes)
                        continue
                    if lanes:
                        self.workers[lanes[0]].post(
                            ("donate", a.recipe, a.worker_id, a.plan))
                        continue
                w.post(("fetch", a.recipe, a.plan))

    # ---------------------------------------------------------- striping ---
    def _start_stripe(self, a: Action, lanes: List[str]):
        """Launch a striped PEER fetch (callers hold the lock): one
        ``donate_chunks`` lane per live donor from the planner's committed
        stripe set, plus — once the template lands — a receiver-side pool
        lane for the immutable params when the node pool holds a copy."""
        sid = next(self._stripe_ids)
        n_pool = 1 if self.snapshots.tier(a.recipe.key()) is not None else 0
        sf = _StripeFetch(sid, a.recipe, a.worker_id, a.plan,
                          tuple(lanes), n_pool)
        self._stripes[sid] = sf
        self._stripe_stats["stripes"] += 1
        for lane, did in enumerate(lanes):
            self.workers[did].post(
                ("donate_chunks", sid, a.recipe, a.worker_id,
                 {"lane": lane, "n_donor": len(lanes), "n_pool": n_pool,
                  "with_template": lane == 0, "ref_ids": None,
                  "cursor": 0}))

    def _stripe_template(self, stripe_id: int, plan, clone, host_halves,
                         nbytes: int, build_seconds: float,
                         aot_seconds: float):
        """Primary-lane template metadata arrived: arm the buffer's
        expected-ref set and activate the pool lane (it needs the plan)."""
        with self._cond:
            sf = self._stripes.get(stripe_id)
            if sf is None or sf.done:
                return
            sf.buffer.set_template(plan, clone, host_halves, nbytes,
                                   build_seconds, aot_seconds)
            if sf.n_pool:
                pool_lane = len(sf.donor_ids)
                sf.lane_owner[pool_lane] = pool_lane
                w = self.workers.get(sf.receiver_id)
                if w is not None and w.alive:
                    w.post(("stripe_pool", stripe_id, sf.recipe,
                            {"lane": pool_lane,
                             "n_donor": len(sf.donor_ids),
                             "n_pool": sf.n_pool}))
        self._maybe_install_stripe(stripe_id)

    def _stripe_deliver(self, stripe_id: int, ref, piece, sha: str,
                        lane: int) -> bool:
        """Verify-and-buffer one chunk from a lane thread. Returns False
        when the lane should stop exporting (corruption failed the lane,
        or the stripe concluded elsewhere)."""
        with self._lock:
            sf = self._stripes.get(stripe_id)
            fault = self._chunk_fault
        if sf is None or sf.done:
            return False
        if fault is not None and fault(stripe_id, ref, lane):
            sha = "0" * 64              # test hook: corrupt in transit
        try:
            sf.buffer.deliver(ref, piece, sha, lane=lane)
        except ChunkCorruptionError:
            traceback.print_exc(file=sys.stderr)
            with self._lock:
                self._stripe_stats["lane_failures"] += 1
            self._stripe_lane_lost(stripe_id, lane)
            return False
        with self._lock:
            self._stripe_stats["chunks"] += 1
        self._maybe_install_stripe(stripe_id)
        return True

    def _maybe_install_stripe(self, stripe_id: int):
        with self._cond:
            sf = self._stripes.get(stripe_id)
            if sf is None or sf.done or sf.buffer.install_posted \
                    or not sf.buffer.complete():
                return
            sf.buffer.install_posted = True
            w = self.workers.get(sf.receiver_id)
            if w is None or not w.alive:
                self._stripe_failed_locked(stripe_id)
                return
            w.post(("install_stripe", stripe_id))

    def _stripe_lane_lost(self, stripe_id: int, phys_lane: int):
        """A physical stripe lane died — corrupt chunk, donor preempted or
        evicted, pool snapshot consumed. Reassign every assignment lane it
        owned to a surviving donor lane (only the UNDELIVERED refs are
        re-exported; the fetch never restarts), or — with no survivors —
        degrade the receiver down the normal fetch ladder."""
        with self._cond:
            sf = self._stripes.get(stripe_id)
            if sf is None or sf.done or phys_lane in sf.failed_lanes:
                return
            sf.failed_lanes.add(phys_lane)
            lost = [al for al, owner in sf.lane_owner.items()
                    if owner == phys_lane]
            if not lost:
                return
            n_donor = len(sf.donor_ids)
            survivors = []
            for lane in range(n_donor):
                if lane in sf.failed_lanes:
                    continue
                dw = self.workers.get(sf.donor_ids[lane])
                if dw is not None and dw.alive:
                    survivors.append(lane)
            plan = sf.buffer.plan
            if survivors:
                sl = survivors[0]
                donor = self.workers[sf.donor_ids[sl]]
                for al in lost:
                    sf.lane_owner[al] = sl
                    spec = {"lane": al, "via_lane": sl, "n_donor": n_donor,
                            "n_pool": sf.n_pool,
                            "with_template": plan is None and al == 0,
                            "ref_ids": None, "cursor": 0}
                    if plan is not None:
                        assigned = assign_lanes(plan.refs, n_donor,
                                                sf.n_pool)[al]
                        spec["ref_ids"] = frozenset(
                            r.id for r in sf.buffer.missing_refs(assigned))
                    donor.post(("donate_chunks", stripe_id, sf.recipe,
                                sf.receiver_id, spec))
                return
            # every donor lane gone: fall down the ladder without
            # restarting — the receiver's Library resolves POOL/DISK/FS/
            # BUILD and the degrade is logged against the PEER promise
            sf.done = True
            self._stripes.pop(stripe_id, None)
            self._stripe_stats["degrades"] += 1
            self._flow_done_locked(sf.plan, failed=True)
            w = self.workers.get(sf.receiver_id)
            if w is not None and w.alive:
                w.post(("install", sf.recipe, None, None,
                        FetchSource.PEER))
            self._cond.notify_all()

    def _stripe_failed_locked(self, stripe_id: int):
        """The stripe cannot conclude (receiver gone): drop it and free
        its planner flows as failed (callers hold the lock)."""
        sf = self._stripes.pop(stripe_id, None)
        if sf is None:
            return
        sf.done = True
        self._flow_done_locked(sf.plan, failed=True)
        self._cond.notify_all()

    def _stripe_failed(self, stripe_id: int):
        with self._cond:
            self._stripe_failed_locked(stripe_id)

    # ---------------------------------------------------------- transfers --
    def _deliver_install(self, receiver_id: str, recipe: ContextRecipe,
                         snap, plan: Optional[TransferPlan],
                         degraded_from: Optional[FetchSource] = None):
        """Hand a donated snapshot (or a None fallback) to the receiving
        worker's mailbox; called from donor threads and drain paths. The
        post happens under the manager lock: preemption flips ``alive``
        and enqueues the retirement under the same lock, so the install
        either lands ahead of the retirement (drained with its flow freed)
        or is rerouted here — never stranded in a dead mailbox."""
        with self._cond:
            w = self.workers.get(receiver_id)
            if w is None or not w.alive:
                # receiver departed mid-transfer: the scheduler already
                # cleaned it up — just free the planner flow
                self._flow_done_locked(plan, failed=True)
                self._cond.notify_all()
                return
            w.post(("install", recipe, snap, plan, degraded_from))

    def _flow_done(self, plan: Optional[TransferPlan],
                   measured_seconds: Optional[float] = None,
                   failed: bool = False):
        with self._lock:
            self._flow_done_locked(plan, measured_seconds, failed=failed)

    def _flow_done_locked(self, plan: Optional[TransferPlan],
                          measured_seconds: Optional[float] = None,
                          failed: bool = False):
        """Report a planned transfer finished: frees the donor/FS slots
        immediately and, when real transfer work was measured (peer
        export + restore), feeds it into the planner's bandwidth
        calibration. Failed transfers are recorded as such — never
        calibrated, never left as phantom in-flight flows (callers hold
        the lock)."""
        if plan is not None:
            self.planner.complete(plan, self.now,
                                  measured_seconds=measured_seconds,
                                  failed=failed)

    def _fail_unresolved(self):
        """Surface scheduler-declared failures (max_attempts exceeded) as
        Future exceptions; callers hold the lock."""
        for task in self.scheduler.failed:
            fut = self._futures.get(task.duplicates_of or task.task_id)
            if fut is not None and not fut.done:
                fut.set_exception(RuntimeError(fut._lost_message()))

    def wait(self, fut: Future, timeout: Optional[float] = None):
        """Block until ``fut`` resolves. Purely event-driven: futures are
        resolved (and workers joined/preempted) under ``self._cond`` with
        a ``notify_all``, so this waits on that condition and re-checks
        only when the runtime actually changed. Raises TimeoutError on
        deadline; RuntimeError when the future can no longer resolve
        (pool drained, or stalled with no live workers and no timeout)."""
        deadline = None if timeout is None else time.monotonic() + timeout
        with self._cond:
            while not fut.done:
                if self.outstanding == 0:
                    raise RuntimeError(fut._lost_message())
                if not self.workers and deadline is None:
                    raise RuntimeError(
                        f"backend stalled with {self.outstanding} task(s) "
                        f"outstanding and no live workers while waiting on "
                        f"{fut.task_id} — add workers or pass "
                        "result(timeout=...)")
                if deadline is None:
                    self._cond.wait()
                else:
                    remaining = deadline - time.monotonic()
                    if remaining <= 0:
                        raise TimeoutError(
                            f"task {fut.task_id} did not complete within "
                            f"{timeout:.3f}s ({self.outstanding} tasks "
                            "still outstanding)")
                    self._cond.wait(remaining)

    def step(self) -> bool:
        """Protocol compatibility for pollers: the concurrent runtime makes
        progress on worker threads, so ``step`` just waits briefly for
        activity. False once nothing is outstanding."""
        with self._cond:
            if self.outstanding == 0:
                return False
            self._cond.wait(0.01)
            return True

    def run_until_idle(self, timeout: Optional[float] = None) -> int:
        """Block until no tasks are queued or running (or the pool has no
        live workers to run them). Returns completions observed while
        draining."""
        deadline = None if timeout is None else time.monotonic() + timeout
        with self._cond:
            start = len(self.scheduler.completions)
            while self.outstanding and self.workers:
                if deadline is not None and time.monotonic() >= deadline:
                    break
                self._cond.wait(0.05)
            return len(self.scheduler.completions) - start

    # ------------------------------------------------------------- status ---
    @property
    def outstanding(self) -> int:
        return self.scheduler.outstanding

    def lookup_task(self, task_id: str) -> Optional[Task]:
        return self.scheduler.tasks.get(task_id)

    def _absorb_library(self, library: Library):
        """Fold a departing worker's Library counters into the manager
        totals (called from the worker thread at retirement/stop)."""
        with self._lock:
            r = self._retired
            for rec in library.records:
                r["cold" if rec.cold else "warm"] += 1
            r["build_seconds"] += library.build_seconds_total
            r["restore_seconds"] += library.restore_seconds_total
            r["builder_calls"] += library.builder_calls
            r["restores"] += library.restores
            r["demotions"] += library.demotions
            r["peer_installs"] += library.peer_installs
            r["peer_exports"] += library.peer_exports
            r["peer_install_seconds"] += library.peer_install_seconds

    # ------------------------------------------------------------- stats ---
    def stats(self) -> Dict:
        with self._lock:
            cold, warm = self._retired["cold"], self._retired["warm"]
            build_s = self._retired["build_seconds"]
            restore_s = self._retired["restore_seconds"]
            builder_calls = self._retired["builder_calls"]
            restores = self._retired["restores"]
            demotions = self._retired["demotions"]
            peer_installs = self._retired["peer_installs"]
            peer_exports = self._retired["peer_exports"]
            peer_install_s = self._retired["peer_install_seconds"]
            for w in self.workers.values():
                for rec in w.library.records:
                    cold += rec.cold
                    warm += not rec.cold
                build_s += w.library.build_seconds_total
                restore_s += w.library.restore_seconds_total
                builder_calls += w.library.builder_calls
                restores += w.library.restores
                demotions += w.library.demotions
                peer_installs += w.library.peer_installs
                peer_exports += w.library.peer_exports
                peer_install_s += w.library.peer_install_seconds
            return {"cold_invocations": cold, "warm_invocations": warm,
                    "context_build_seconds": build_s,
                    "context_restore_seconds": restore_s,
                    "builder_calls": builder_calls,
                    "context_restores": restores,
                    "context_demotions": demotions,
                    "peer_installs": peer_installs,
                    "peer_exports": peer_exports,
                    "peer_install_seconds": peer_install_s,
                    "completed": len(self.scheduler.completions),
                    "snapshot_pool": self.snapshots.stats(),
                    "striping": dict(self._stripe_stats),
                    "transfer": self.planner.stats(self.now)}
