"""Context recipes, materialized contexts and context snapshots — the
paper's first-class entity through its whole residency lifecycle.

A *recipe* is everything needed to (re)build an LLM context anywhere in the
cluster: the constructor function, its inputs, the software environment, and
the byte footprint of each stage (shared-FS artifact -> local disk -> host
RAM -> device HBM). A *context* is one materialization of a recipe on one
worker; the Library holds it across task executions (full-context mode).

A *snapshot* (:class:`ContextSnapshot`) is a demoted context: the device-
resident state (weights, KV cache, per-slot decode state, RNG) pulled to
host RAM via ``jax.device_get``, with the AOT-compiled executables retained
as host metadata. Snapshots can spill further to local disk through
``repro.checkpoint.io`` and are promoted back with ``restore_context`` —
no builder call, no XLA compile, bit-identical state.

Recipes hash stably (``key()``), so the scheduler, stores, and transfer
planner all agree on identity without shipping the payload around.
"""

from __future__ import annotations

import hashlib
import json
import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, Optional, Tuple

GB = 1024 ** 3


def _arg_token(x: Any) -> str:
    """Stable identity token for a builder argument. ``repr`` alone is not
    enough: numpy/JAX arrays truncate their repr (distinct arrays would
    collide), so array-likes hash their bytes. Objects with default reprs
    (memory addresses) stay distinct per object — conservative: logically
    equal but distinct objects rebuild rather than alias."""
    if isinstance(x, (str, int, float, bool, bytes, type(None))):
        return repr(x)
    if isinstance(x, (tuple, list)):
        return "[" + ",".join(_arg_token(i) for i in x) + "]"
    if isinstance(x, dict):
        items = sorted(x.items(), key=lambda kv: repr(kv[0]))
        return "{" + ",".join(f"{_arg_token(k)}:{_arg_token(v)}"
                              for k, v in items) + "}"
    if hasattr(x, "__array__") and hasattr(x, "shape"):   # numpy/JAX array
        import numpy as np
        arr = np.asarray(x)
        digest = hashlib.sha256(arr.tobytes()).hexdigest()[:16]
        return f"array{arr.shape}:{arr.dtype}:{digest}"
    return f"{type(x).__qualname__}:{repr(x)}"


@dataclass(frozen=True)
class ContextRecipe:
    """Declarative description of an LLM context.

    ``builder`` runs ONCE per worker (the paper's ``load_model``); its return
    value is held by the Library and handed to every invocation. Footprints
    default to the paper's measured SmolLM2 numbers (3.7 GB model artifact,
    7.4 GB loaded, 10.5 GB conda env).
    """

    name: str
    builder: Optional[Callable[..., Any]] = None
    builder_args: Tuple = ()
    builder_kwargs: Tuple = ()                  # tuple of (k, v) pairs
    model_key: str = ""                         # ModelConfig.key() if any
    artifact_bytes: int = int(3.7 * GB)         # shared-FS model payload
    env_bytes: int = int(10.5 * GB)             # software deps payload
    host_bytes: int = int(7.4 * GB)             # resident host RAM
    device_bytes: int = int(3.7 * GB)           # resident HBM
    version: int = 0

    def key(self) -> str:
        # cached: the scheduler recomputes keys in per-dispatch hot loops
        cached = self.__dict__.get("_key")
        if cached is not None:
            return cached
        ident = {
            "name": self.name, "model_key": self.model_key,
            "artifact": self.artifact_bytes, "env": self.env_bytes,
            "version": self.version,
            "builder": getattr(self.builder, "__qualname__", str(self.builder)),
            # same builder with different inputs is a DIFFERENT context
            "args": _arg_token(self.builder_args),
            "kwargs": _arg_token(self.builder_kwargs),
        }
        blob = json.dumps(ident, sort_keys=True)
        key = hashlib.sha256(blob.encode()).hexdigest()[:16]
        object.__setattr__(self, "_key", key)
        return key

    @property
    def transfer_bytes(self) -> int:
        """Bytes pulled when bootstrapping a cold worker (artifact + env)."""
        return self.artifact_bytes + self.env_bytes

    def with_builder(self, builder, *args, **kwargs) -> "ContextRecipe":
        import dataclasses as dc
        return dc.replace(self, builder=builder, builder_args=args,
                          builder_kwargs=tuple(sorted(kwargs.items())))


@dataclass
class Context:
    """A materialized recipe living on one worker."""

    recipe: ContextRecipe
    value: Any = None
    worker_id: str = ""
    created_at: float = field(default_factory=time.monotonic)
    build_seconds: float = 0.0
    aot_seconds: float = 0.0       # AOT executable warm-up inside the build
    uses: int = 0
    last_used: float = field(default_factory=time.monotonic)
    restored: bool = False         # promoted from a snapshot, not built
    restore_seconds: float = 0.0   # real promotion cost when restored
    # per-stage (disk/h2d) split of a streamed restore, {stage: [bytes,
    # seconds]} — feeds TransferPlanner.observe_stage calibration
    stage_seconds: Dict[str, list] = field(default_factory=dict)

    @property
    def key(self) -> str:
        return self.recipe.key()

    def touch(self):
        self.uses += 1
        self.last_used = time.monotonic()


def _reachable(value: Any):
    """The context value plus one level of dict/list/tuple containers —
    the shapes context builders actually return."""
    items = [value]
    if isinstance(value, dict):
        items += list(value.values())
    elif isinstance(value, (list, tuple)):
        items += list(value)
    return items


def _warmable(value: Any):
    """Yield AOT-warmable engines reachable from a context value.

    Duck-typed (``warm_executables``) so core never imports the serving
    layer."""
    for v in _reachable(value):
        if callable(getattr(v, "warm_executables", None)):
            yield v


def _offloadable(value: Any):
    """Yield objects reachable from a context value that support physical
    device<->host state movement (duck-typed ``offload_device_state`` /
    ``restore_device_state`` — e.g. :class:`repro.serving.InferenceEngine`).
    Deterministic order: demote and restore walk the same sequence."""
    for v in _reachable(value):
        if callable(getattr(v, "offload_device_state", None)) and \
                callable(getattr(v, "restore_device_state", None)):
            yield v


def materialize(recipe: ContextRecipe, worker_id: str = "local") -> Context:
    """Run the builder (the one-time expensive startup) and wrap it.

    Materialization also AOT-compiles any inference engines the builder
    returned (``warm_executables``: the decode megastep + every
    prefill-bucket executable), so the compiled executables are part of
    the resident context and every task against a warm context performs
    zero compiles — the paper's full-context amortization extended down
    to the XLA executable level."""
    t0 = time.monotonic()
    value = None
    if recipe.builder is not None:
        value = recipe.builder(*recipe.builder_args,
                               **dict(recipe.builder_kwargs))
    aot = 0.0
    for engine in _warmable(value):
        aot += engine.warm_executables()
    return Context(recipe=recipe, value=value, worker_id=worker_id,
                   build_seconds=time.monotonic() - t0, aot_seconds=aot)


# ----------------------------------------------------------- snapshots -----
def _tree_nbytes(tree: Any) -> int:
    import numpy as np
    total = 0
    for leaf in _tree_leaves(tree):
        if hasattr(leaf, "nbytes"):
            total += int(leaf.nbytes)
        elif hasattr(leaf, "size") and hasattr(leaf, "dtype"):
            total += int(leaf.size) * np.dtype(leaf.dtype).itemsize
    return total


def _tree_leaves(tree: Any):
    import jax
    return jax.tree_util.tree_leaves(tree)


@dataclass
class ContextSnapshot:
    """A demoted context: the materialized value with its device state
    pulled off the accelerator.

    ``value`` is the builder's return object (engine instances, tokenizers,
    plain dicts) with every offloadable component's device arrays REMOVED —
    the AOT-compiled executables stay attached to those components as host
    metadata, which is what makes promotion compile-free. ``host_state``
    maps component index -> host (numpy) pytree of that component's device
    state; for values with no offloadable components the value itself IS
    the (host) state and ``host_state`` is empty.

    Lifecycle::

        snapshot_context(ctx)   DEVICE    -> HOST_RAM   (jax.device_get)
        snap.spill(store)       HOST_RAM  -> LOCAL_DISK (checkpoint/io npz)
        snap.unspill(store)     LOCAL_DISK-> HOST_RAM   (npz load)
        restore_context(snap)   HOST_RAM  -> DEVICE     (jax.device_put)

    A snapshot is single-owner: restoring it moves the value object to the
    restoring worker (see ``repro.core.store.SnapshotPool.take``).
    """

    recipe: ContextRecipe
    value: Any
    host_state: Dict[str, Any]
    nbytes: int
    build_seconds: float = 0.0
    aot_seconds: float = 0.0
    spilled: bool = False            # arrays currently on LOCAL_DISK
    spill_key: str = ""
    created_at: float = field(default_factory=time.monotonic)
    last_used: float = field(default_factory=time.monotonic)
    demote_seconds: float = 0.0

    @property
    def key(self) -> str:
        return self.recipe.key()

    @property
    def tier(self) -> int:
        """1 == Tier.LOCAL_DISK, 2 == Tier.HOST_RAM (int values match the
        ``repro.core.store.Tier`` IntEnum; typed as int to avoid a circular
        import)."""
        return 1 if self.spilled else 2

    # ----------------------------------------------------------- spilling --
    def spill(self, spill_store, chunk_bytes: int = 64 << 20) -> str:
        """Write the host arrays to local disk (atomic npz + manifest via
        ``repro.checkpoint.io``) and release the host RAM copy. A shape/
        dtype skeleton stays in RAM so ``unspill`` can rebuild the exact
        pytree structure."""
        if self.spilled:
            return self.spill_key
        import uuid

        import jax
        # generation-unique path: two snapshots of the SAME context can be
        # in flight concurrently (e.g. demote on two workers) — sharing a
        # directory would let the loser's discard delete the winner's data
        self.spill_key = f"ctx_{self.key}_{uuid.uuid4().hex[:8]}"
        # paged-KV components (their offload dict carries the live-page
        # index) stream their cache leaves through checkpoint/io in
        # PAGE-ALIGNED chunks: each gathered leaf is sliced along its own
        # page axis (``_paged_page_axes``, a pytree of ints mirroring the
        # cache) in whole-page groups, so every chunk boundary is a page
        # boundary — integrity (per-chunk sha256) and partial reads
        # (io.load_chunks) address whole pages, never splitting one
        from repro.checkpoint.io import _flatten, plan_chunk_rows
        chunk_rows: dict = {}
        for name, comp in self.host_state.items():
            if not (isinstance(comp, dict) and "_paged_live_ids" in comp):
                continue
            axes = comp.get("_paged_page_axes")
            if axes is None:                    # pre-axis snapshots
                chunk_rows[f"{name}/cache"] = 8
                continue
            for key, ax in _flatten({"cache": axes}).items():
                chunk_rows[f"{name}/{key}"] = {"rows": 8, "axis": int(ax)}
        # every remaining large leaf (the weights) chunks too — per-chunk
        # sha256, so a streamed restore verifies entry-by-entry instead of
        # re-hashing the whole payload file, and a corrupt chunk is
        # addressable without discarding the rest
        for key, spec in plan_chunk_rows(self.host_state,
                                         chunk_bytes).items():
            if not any(key == p or key.startswith(p + "/")
                       for p in chunk_rows):
                chunk_rows[key] = spec
        spill_store.save(self.spill_key, self.host_state,
                         meta={"context_key": self.key,
                               "recipe": self.recipe.name},
                         chunk_rows=chunk_rows or None)
        self._skeleton = jax.tree_util.tree_map(
            lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype)
            if hasattr(a, "shape") else a, self.host_state)
        self.host_state = {}
        self.spilled = True
        return self.spill_key

    def unspill(self, spill_store):
        """Read the arrays back LOCAL_DISK -> HOST_RAM and delete the disk
        copy: snapshots are single-owner, so promotion CONSUMES the spill
        (leaving it would leak one GB-scale npz directory per
        demote-to-disk/restore cycle)."""
        if not self.spilled:
            return
        self.host_state, _ = spill_store.load(self.spill_key,
                                              like=self._skeleton)
        spill_store.delete(self.spill_key)
        self.spill_key = ""
        self._skeleton = None
        self.spilled = False

    def discard(self, spill_store):
        """Drop the on-disk copy (pool eviction of a spilled snapshot)."""
        if self.spilled and self.spill_key:
            spill_store.delete(self.spill_key)


class PeerExportError(RuntimeError):
    """The context value holds a device-stateful component that cannot be
    cloned for a peer transfer (no ``clone_offloaded``/``export_template``
    hooks) — the receiver must fall back down the fetch ladder."""


def _clone_item(v: Any) -> Any:
    """Clone one reachable component for a peer transfer. Device-stateful
    components must provide the transfer duck-type (``clone_offloaded`` —
    a structural twin sharing the AOT executables, device state detached —
    plus ``export_template``); plain host objects are deep-copied."""
    if callable(getattr(v, "clone_offloaded", None)) and \
            callable(getattr(v, "export_template", None)):
        return v.clone_offloaded()
    if callable(getattr(v, "offload_device_state", None)):
        raise PeerExportError(
            f"{type(v).__qualname__} is device-stateful but does not "
            "support peer transfer (clone_offloaded/export_template)")
    import copy
    return copy.deepcopy(v)


def _exportable(value: Any):
    """Donor components whose template state ships in the transfer.

    Membership is ``_offloadable`` AND the transfer hooks: the receiver's
    ``restore_context`` feeds ``host_state`` by index over the clone's
    ``_offloadable`` walk, so the two enumerations must agree exactly — a
    component with export hooks but no offload/restore hooks is cloned
    (``_clone_item``) but ships no template, matching the restore side
    that would never touch it."""
    for v in _offloadable(value):
        if callable(getattr(v, "export_template", None)) and \
                callable(getattr(v, "clone_offloaded", None)):
            yield v


def export_context(ctx: Context) -> ContextSnapshot:
    """Donor side of a peer-to-peer context bootstrap (FetchSource.PEER).

    Unlike :func:`snapshot_context` (demotion — destructive, the donor
    loses its device state), export builds a TEMPLATE copy while the donor
    keeps serving: each device-stateful component contributes a pristine
    host-side template (weights copied via ``jax.device_get``, per-slot
    decode state blank) via ``export_template``, and the snapshot's value
    is a structural clone (``clone_offloaded``) that SHARES the donor's
    AOT-compiled executables in-process — which is why the receiver's
    restore performs zero builder calls and zero XLA compiles. Plain host
    components (tokenizers, configs) are deep-copied.

    Raises :class:`PeerExportError` when a device-stateful component lacks
    the transfer hooks; callers fall back down the fetch ladder."""
    t0 = time.monotonic()
    value = ctx.value
    if isinstance(value, dict):
        clone = {k: _clone_item(v) for k, v in value.items()}
    elif isinstance(value, (list, tuple)):
        clone = type(value)(_clone_item(v) for v in value)
    else:
        clone = _clone_item(value)
    host_state: Dict[str, Any] = {}
    for i, comp in enumerate(_exportable(value)):
        host_state[f"c{i}"] = comp.export_template()
    nbytes = _tree_nbytes(host_state) if host_state \
        else ctx.recipe.host_bytes
    return ContextSnapshot(recipe=ctx.recipe, value=clone,
                           host_state=host_state, nbytes=nbytes,
                           build_seconds=ctx.build_seconds,
                           aot_seconds=ctx.aot_seconds,
                           demote_seconds=time.monotonic() - t0)


def stripe_export_state(ctx: Context) -> Dict[str, Any]:
    """Device halves of every exportable component that supports the split
    export hooks — DEVICE references, no ``device_get``. This is the tree
    a streamed (chunked) export plans over: params never mutate during
    serving, so per-chunk ``device_get``s interleaved with decode work
    read a coherent payload."""
    device: Dict[str, Any] = {}
    for i, comp in enumerate(_exportable(ctx.value)):
        fn = getattr(comp, "export_template_device", None)
        if callable(fn):
            device[f"c{i}"] = fn()
    return device


def stripe_export_template(ctx: Context):
    """Metadata half of a streamed export: the structural clone (shares
    the donor's AOT executables in-process) plus each component's
    synthesized host half. Components lacking the split hooks ship their
    WHOLE template in the host half (monolithic for that component only —
    one ``device_get``), so streamed transfers degrade gracefully to
    :func:`export_context` semantics. Returns ``(clone, host_halves,
    host_nbytes)``; add the device-half plan's total for the full template
    size. Raises :class:`PeerExportError` exactly where
    :func:`export_context` would."""
    value = ctx.value
    if isinstance(value, dict):
        clone = {k: _clone_item(v) for k, v in value.items()}
    elif isinstance(value, (list, tuple)):
        clone = type(value)(_clone_item(v) for v in value)
    else:
        clone = _clone_item(value)
    host_halves: Dict[str, Any] = {}
    for i, comp in enumerate(_exportable(value)):
        if callable(getattr(comp, "export_template_device", None)) and \
                callable(getattr(comp, "export_template_host", None)):
            host_halves[f"c{i}"] = comp.export_template_host()
        else:
            host_halves[f"c{i}"] = comp.export_template()
    return clone, host_halves, _tree_nbytes(host_halves)


def snapshot_context(ctx: Context) -> ContextSnapshot:
    """Demote DEVICE -> HOST_RAM: pull every offloadable component's device
    state to host numpy (one ``jax.device_get`` per component) and detach
    it from the accelerator. The value object (with its AOT executables)
    rides along as host metadata; values with no offloadable components
    (plain host objects) snapshot as-is."""
    t0 = time.monotonic()
    host_state: Dict[str, Any] = {}
    for i, comp in enumerate(_offloadable(ctx.value)):
        host_state[f"c{i}"] = comp.offload_device_state()
    nbytes = _tree_nbytes(host_state) if host_state \
        else ctx.recipe.host_bytes
    return ContextSnapshot(recipe=ctx.recipe, value=ctx.value,
                           host_state=host_state, nbytes=nbytes,
                           build_seconds=ctx.build_seconds,
                           aot_seconds=ctx.aot_seconds,
                           demote_seconds=time.monotonic() - t0)


def _streamed_unspill(snap: ContextSnapshot, spill_store,
                      stage_seconds: Dict[str, list]):
    """LOCAL_DISK -> DEVICE without materializing the whole host snapshot:
    a reader thread does pure disk IO (raw npz chunks, no hashing — the
    whole-file sha pass is skipped entirely) while this thread verifies
    each chunk's manifest digest, concatenates completed leaves and
    ``device_put``s them, so verify/assembly/h2d of chunk *i* overlap the
    disk read of chunk *i+1* (double-buffered promotion with the compute
    half off the IO thread). Small metadata leaves stay host numpy;
    ``jax.device_put`` of an already-device array is pass-through, so
    ``restore_device_state`` downstream is unchanged. Consumes the spill
    like ``unspill``. Corrupt chunks raise ``ChunkCorruptionError`` from
    this thread, naming the entry."""
    import queue as _queue
    import threading

    import jax
    import numpy as np
    from repro.checkpoint import io as ckio
    directory = spill_store.path(snap.spill_key)
    fifo: _queue.Queue = _queue.Queue(maxsize=4)
    fail: list = []

    def _reader():
        t0 = time.monotonic()
        nbytes = 0
        try:
            for item in ckio.iter_raw_chunks(directory):
                nbytes += int(item[4].nbytes)
                fifo.put(item)
        except BaseException as exc:            # surface on the main thread
            fail.append(exc)
        finally:
            stage_seconds["disk"] = [nbytes, time.monotonic() - t0]
            fifo.put(None)

    reader = threading.Thread(target=_reader, daemon=True,
                              name="pcm-unspill-reader")
    reader.start()
    flat: Dict[str, Any] = {}
    parts: list = []
    corrupt = None
    t_h2d = 0.0
    h2d_bytes = 0
    while True:
        item = fifo.get()
        if item is None:
            break
        if corrupt is not None:
            continue              # drain so the reader can finish and exit
        key, index, count, axis, arr, want = item
        try:
            ckio.verify_chunk(key, index, arr, want, where=directory)
        except ckio.ChunkCorruptionError as exc:
            corrupt = exc
            continue
        if count > 1:
            parts.append(arr)
            if len(parts) < count:
                continue
            arr = np.concatenate(parts, axis=axis)
            parts = []
        if arr.nbytes >= (1 << 20):
            t0 = time.monotonic()
            flat[key] = jax.device_put(arr)
            t_h2d += time.monotonic() - t0
            h2d_bytes += int(arr.nbytes)
        else:
            flat[key] = arr
    reader.join()
    stage_seconds["h2d"] = [h2d_bytes, t_h2d]
    if corrupt is not None:
        raise corrupt
    if fail:
        raise fail[0]
    leaves_with_path = jax.tree_util.tree_flatten_with_path(
        snap._skeleton)[0]
    treedef = jax.tree_util.tree_structure(snap._skeleton)
    ordered = [flat["/".join(ckio._path_str(p) for p in path)]
               for path, _ in leaves_with_path]
    snap.host_state = jax.tree_util.tree_unflatten(treedef, ordered)
    spill_store.delete(snap.spill_key)
    snap.spill_key = ""
    snap._skeleton = None
    snap.spilled = False


def restore_context(snap: ContextSnapshot, worker_id: str = "local",
                    spill_store=None, streamed: bool = False) -> Context:
    """Promote a snapshot back to a live device-resident Context.

    LOCAL_DISK snapshots are unspilled to host first (requires
    ``spill_store``), then each offloadable component's state is pushed
    back with ``jax.device_put``. With ``streamed=True`` a spilled
    snapshot instead streams entry-by-entry to device (per-entry digest
    verification, read/verify of the next entry overlapping the
    ``device_put`` of the current one — see :func:`_streamed_unspill`).
    No builder call, no XLA compile: the executables never left the
    component objects. ``restore_seconds`` on the returned Context records
    the real promotion cost; ``stage_seconds`` carries the per-stage
    (disk/h2d) split for pipeline calibration when streamed."""
    t0 = time.monotonic()
    stage_seconds: Dict[str, list] = {}
    if snap.spilled:
        if spill_store is None:
            raise ValueError(
                f"snapshot {snap.key} is spilled to disk; a spill store is "
                "required to restore it")
        if streamed:
            _streamed_unspill(snap, spill_store, stage_seconds)
        else:
            snap.unspill(spill_store)
    for i, comp in enumerate(_offloadable(snap.value)):
        comp.restore_device_state(snap.host_state[f"c{i}"])
    snap.host_state = {}
    ctx = Context(recipe=snap.recipe, value=snap.value, worker_id=worker_id,
                  build_seconds=snap.build_seconds,
                  aot_seconds=snap.aot_seconds)
    ctx.restore_seconds = time.monotonic() - t0
    ctx.stage_seconds = stage_seconds
    ctx.restored = True
    return ctx
