"""Context recipes and materialized contexts — the paper's first-class
entity.

A *recipe* is everything needed to (re)build an LLM context anywhere in the
cluster: the constructor function, its inputs, the software environment, and
the byte footprint of each stage (shared-FS artifact -> local disk -> host
RAM -> device HBM). A *context* is one materialization of a recipe on one
worker; the Library holds it across task executions (full-context mode).

Recipes hash stably (``key()``), so the scheduler, stores, and transfer
planner all agree on identity without shipping the payload around.
"""

from __future__ import annotations

import hashlib
import json
import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, Optional, Tuple

GB = 1024 ** 3


def _arg_token(x: Any) -> str:
    """Stable identity token for a builder argument. ``repr`` alone is not
    enough: numpy/JAX arrays truncate their repr (distinct arrays would
    collide), so array-likes hash their bytes. Objects with default reprs
    (memory addresses) stay distinct per object — conservative: logically
    equal but distinct objects rebuild rather than alias."""
    if isinstance(x, (str, int, float, bool, bytes, type(None))):
        return repr(x)
    if isinstance(x, (tuple, list)):
        return "[" + ",".join(_arg_token(i) for i in x) + "]"
    if isinstance(x, dict):
        items = sorted(x.items(), key=lambda kv: repr(kv[0]))
        return "{" + ",".join(f"{_arg_token(k)}:{_arg_token(v)}"
                              for k, v in items) + "}"
    if hasattr(x, "__array__") and hasattr(x, "shape"):   # numpy/JAX array
        import numpy as np
        arr = np.asarray(x)
        digest = hashlib.sha256(arr.tobytes()).hexdigest()[:16]
        return f"array{arr.shape}:{arr.dtype}:{digest}"
    return f"{type(x).__qualname__}:{repr(x)}"


@dataclass(frozen=True)
class ContextRecipe:
    """Declarative description of an LLM context.

    ``builder`` runs ONCE per worker (the paper's ``load_model``); its return
    value is held by the Library and handed to every invocation. Footprints
    default to the paper's measured SmolLM2 numbers (3.7 GB model artifact,
    7.4 GB loaded, 10.5 GB conda env).
    """

    name: str
    builder: Optional[Callable[..., Any]] = None
    builder_args: Tuple = ()
    builder_kwargs: Tuple = ()                  # tuple of (k, v) pairs
    model_key: str = ""                         # ModelConfig.key() if any
    artifact_bytes: int = int(3.7 * GB)         # shared-FS model payload
    env_bytes: int = int(10.5 * GB)             # software deps payload
    host_bytes: int = int(7.4 * GB)             # resident host RAM
    device_bytes: int = int(3.7 * GB)           # resident HBM
    version: int = 0

    def key(self) -> str:
        # cached: the scheduler recomputes keys in per-dispatch hot loops
        cached = self.__dict__.get("_key")
        if cached is not None:
            return cached
        ident = {
            "name": self.name, "model_key": self.model_key,
            "artifact": self.artifact_bytes, "env": self.env_bytes,
            "version": self.version,
            "builder": getattr(self.builder, "__qualname__", str(self.builder)),
            # same builder with different inputs is a DIFFERENT context
            "args": _arg_token(self.builder_args),
            "kwargs": _arg_token(self.builder_kwargs),
        }
        blob = json.dumps(ident, sort_keys=True)
        key = hashlib.sha256(blob.encode()).hexdigest()[:16]
        object.__setattr__(self, "_key", key)
        return key

    @property
    def transfer_bytes(self) -> int:
        """Bytes pulled when bootstrapping a cold worker (artifact + env)."""
        return self.artifact_bytes + self.env_bytes

    def with_builder(self, builder, *args, **kwargs) -> "ContextRecipe":
        import dataclasses as dc
        return dc.replace(self, builder=builder, builder_args=args,
                          builder_kwargs=tuple(sorted(kwargs.items())))


@dataclass
class Context:
    """A materialized recipe living on one worker."""

    recipe: ContextRecipe
    value: Any = None
    worker_id: str = ""
    created_at: float = field(default_factory=time.monotonic)
    build_seconds: float = 0.0
    aot_seconds: float = 0.0       # AOT executable warm-up inside the build
    uses: int = 0
    last_used: float = field(default_factory=time.monotonic)

    @property
    def key(self) -> str:
        return self.recipe.key()

    def touch(self):
        self.uses += 1
        self.last_used = time.monotonic()


def _warmable(value: Any):
    """Yield AOT-warmable engines reachable from a context value.

    Duck-typed (``warm_executables``) so core never imports the serving
    layer; looks at the value itself plus one level of dict/list/tuple
    containers — the shapes context builders actually return."""
    items = [value]
    if isinstance(value, dict):
        items += list(value.values())
    elif isinstance(value, (list, tuple)):
        items += list(value)
    for v in items:
        if callable(getattr(v, "warm_executables", None)):
            yield v


def materialize(recipe: ContextRecipe, worker_id: str = "local") -> Context:
    """Run the builder (the one-time expensive startup) and wrap it.

    Materialization also AOT-compiles any inference engines the builder
    returned (``warm_executables``: the decode megastep + every
    prefill-bucket executable), so the compiled executables are part of
    the resident context and every task against a warm context performs
    zero compiles — the paper's full-context amortization extended down
    to the XLA executable level."""
    t0 = time.monotonic()
    value = None
    if recipe.builder is not None:
        value = recipe.builder(*recipe.builder_args,
                               **dict(recipe.builder_kwargs))
    aot = 0.0
    for engine in _warmable(value):
        aot += engine.warm_executables()
    return Context(recipe=recipe, value=value, worker_id=worker_id,
                   build_seconds=time.monotonic() - t0, aot_seconds=aot)
