"""Tiered per-worker context store.

Tiers mirror the paper's startup pipeline: SHARED_FS -> LOCAL_DISK ->
HOST_RAM -> DEVICE. The three application transformations map onto how deep
residency is allowed to persist across tasks:

  context-agnostic : nothing persists (store cleared after every task)
  partial-context  : LOCAL_DISK persists (artifact + env cached on disk;
                     HBM state still rebuilt per task)
  full-context     : DEVICE persists (the Library keeps the loaded model)

Capacity-bounded with LRU eviction per tier; eviction from a tier demotes
nothing (re-fetch from below), matching worker sandbox semantics.
"""

from __future__ import annotations

import enum
import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Set

from repro.core.context import GB, ContextRecipe


class Tier(enum.IntEnum):
    SHARED_FS = 0      # always available (the cluster filesystem)
    LOCAL_DISK = 1
    HOST_RAM = 2
    DEVICE = 3


class ContextMode(enum.Enum):
    AGNOSTIC = "agnostic"
    PARTIAL = "partial"
    FULL = "full"

    @property
    def persist_tier(self) -> Tier:
        return {ContextMode.AGNOSTIC: Tier.SHARED_FS,
                ContextMode.PARTIAL: Tier.LOCAL_DISK,
                ContextMode.FULL: Tier.DEVICE}[self]


@dataclass
class _Entry:
    key: str
    nbytes: int
    last_used: float = field(default_factory=time.monotonic)


class ContextStore:
    """Tracks which context keys are resident at which tier of one worker."""

    def __init__(self, disk_bytes: int = 70 * GB, host_bytes: int = 10 * GB,
                 device_bytes: int = 24 * GB):
        self.capacity = {Tier.LOCAL_DISK: disk_bytes,
                         Tier.HOST_RAM: host_bytes,
                         Tier.DEVICE: device_bytes}
        self._tiers: Dict[Tier, Dict[str, _Entry]] = {
            Tier.LOCAL_DISK: {}, Tier.HOST_RAM: {}, Tier.DEVICE: {}}
        self.evictions = 0
        self.pinned: Set[str] = set()

    # ------------------------------------------------------------- pinning --
    def pin(self, key: str):
        """Exempt ``key`` from LRU eviction and mode cleanup. Pinning can
        overcommit a tier: admission never evicts a pinned entry."""
        self.pinned.add(key)

    def unpin(self, key: str):
        self.pinned.discard(key)

    def has(self, key: str, tier: Tier) -> bool:
        if tier == Tier.SHARED_FS:
            return True
        return key in self._tiers[tier]

    def highest_tier(self, key: str) -> Tier:
        for tier in (Tier.DEVICE, Tier.HOST_RAM, Tier.LOCAL_DISK):
            if key in self._tiers[tier]:
                return tier
        return Tier.SHARED_FS

    def used(self, tier: Tier) -> int:
        return sum(e.nbytes for e in self._tiers[tier].values())

    def admit(self, key: str, tier: Tier, nbytes: int, now: float = None
              ) -> List[str]:
        """Place key at tier, LRU-evicting as needed. Returns evicted keys."""
        if tier == Tier.SHARED_FS:
            return []
        if nbytes > self.capacity[tier]:
            raise ValueError(
                f"context {key} ({nbytes / GB:.1f} GB) exceeds tier "
                f"{tier.name} capacity ({self.capacity[tier] / GB:.1f} GB)")
        entries = self._tiers[tier]
        evicted = []
        while self.used(tier) + nbytes > self.capacity[tier] and entries:
            victim = min((e for k, e in entries.items()
                          if k != key and k not in self.pinned),
                         key=lambda e: e.last_used, default=None)
            if victim is None:
                break
            del entries[victim.key]
            evicted.append(victim.key)
            self.evictions += 1
        now = time.monotonic() if now is None else now
        entries[key] = _Entry(key=key, nbytes=nbytes, last_used=now)
        return evicted

    def admit_recipe(self, recipe: ContextRecipe, upto: Tier,
                     now: float = None) -> List[str]:
        """Admit a recipe's footprint at every tier up to ``upto``."""
        key = recipe.key()
        evicted = []
        if upto >= Tier.LOCAL_DISK:
            evicted += self.admit(key, Tier.LOCAL_DISK,
                                  recipe.transfer_bytes, now)
        if upto >= Tier.HOST_RAM:
            evicted += self.admit(key, Tier.HOST_RAM, recipe.host_bytes, now)
        if upto >= Tier.DEVICE:
            evicted += self.admit(key, Tier.DEVICE, recipe.device_bytes, now)
        return evicted

    def touch(self, key: str, now: float = None):
        now = time.monotonic() if now is None else now
        for entries in self._tiers.values():
            if key in entries:
                entries[key].last_used = now

    def drop(self, key: str, down_to: Tier = Tier.SHARED_FS,
             force: bool = False):
        """Remove residency above ``down_to`` (mode cleanup after a task).
        Pinned keys survive unless ``force`` (worker actually gone)."""
        if key in self.pinned and not force:
            return
        for tier, entries in self._tiers.items():
            if tier > down_to:
                entries.pop(key, None)

    def clear(self, force: bool = False):
        for entries in self._tiers.values():
            if force or not self.pinned:
                entries.clear()
            else:
                for k in [k for k in entries if k not in self.pinned]:
                    del entries[k]

    def keys(self, tier: Tier) -> Set[str]:
        if tier == Tier.SHARED_FS:
            return set()
        return set(self._tiers[tier])
