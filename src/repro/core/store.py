"""Tiered per-worker context store + the node-level snapshot pool.

Tiers mirror the paper's startup pipeline: SHARED_FS -> LOCAL_DISK ->
HOST_RAM -> DEVICE. The three application transformations map onto how deep
residency is allowed to persist across tasks:

  context-agnostic : nothing persists (store cleared after every task)
  partial-context  : LOCAL_DISK persists (artifact + env cached on disk;
                     HBM state still rebuilt per task)
  full-context     : DEVICE persists (the Library keeps the loaded model)

Residency state machine of one context on one worker::

                 fetch/build                 task start
    SHARED_FS ---------------> LOCAL_DISK ---------------> DEVICE
        ^                        |    ^                      |  ^
        |        drop(force)     |    |   promote (restore   |  | PEER
        +------------------------+    |   from snapshot,     |  | transfer
                                      |   zero compiles)     |  | (donor
                                      |                      v  | export ->
                                      +----- HOST_RAM <------+  | receiver
                                         demote (jax.device_get | restore;
                                         snapshot of params +   | donor
                                         engine state); HOST_RAM| keeps its
                                         spills to LOCAL_DISK   | DEVICE
                                         via checkpoint/io when | copy)
                                         the pool is over       |
                                         capacity      [warm peer worker]

DEVICE->HOST_RAM demotion and HOST_RAM->LOCAL_DISK spill are PHYSICAL in
the live runtime: the bytes move (see :class:`SnapshotPool` and
``repro.core.context.ContextSnapshot``), and promotion restores the
materialized context without re-running the builder or recompiling.

Every snapshot-moving edge above also exists as a cross-NODE **WIRE**
edge when the worker is a process on another machine (versioned
``repro.core.wire`` blobs — chunked-sha256 arrays, executables as
AOTRecipes — over the ``repro.core.transport`` socket frames)::

        node A (remote process)                 manager host
    DEVICE --demote--> node pool ==demoted_ctx==> manager POOL
       |                                            |    (HOST_RAM,
       |  stripe_chunk frames                       |     spills to
       |  (per-chunk sha256,              ==install=+     LOCAL_DISK)
       |  striped across donors)          |
       +===========================> node B DEVICE (adopt/restore,
                 PEER over the wire        zero builds, AOT cache hits)

The FetchSource vocabulary is unchanged — a wire install still lands as
PEER/POOL/DISK in the fetch history — so live-vs-sim decision parity
holds across process boundaries.

Every edge below DEVICE moves LIVE bytes, not allocated capacity: a paged
engine (``repro.serving.paged``) snapshots only the KV pages its requests
actually own, so snapshot ``nbytes`` — and with it SnapshotPool occupancy,
spill I/O, TransferPlanner predictions and peer-transfer seconds — scales
with live context. The allocated pool (``capacity_bytes``) is an
HBM-only cost that is rebuilt zero-filled at restore; contiguous slot
caches estimate the same split via ``repro.serving.kvcache.live_bytes``.

Pages can be SHARED: with prefix sharing on, a page may be referenced by
several slot reservations and by the engine's radix prefix cache at once
(``repro.serving.paged.PrefixCache`` — copy-on-write page-level prefix
sharing). The live set that demotes is the refcount>0 set, deduplicated:
a page three requests map is one page of snapshot bytes, so sharing
shrinks every rung below DEVICE exactly as it shrinks HBM. Demotion
carries the per-page refcounts alongside the live-page index (restore
validates them; the allocator and prefix cache ride on the engine object
as host metadata, like the AOT executables), and the HOST_RAM ->
LOCAL_DISK spill streams paged cache leaves through ``checkpoint/io`` in
PAGE-ALIGNED chunks — one manifest sha256 per chunk of whole pages, so
spill integrity and partial reads (``io.load_chunks``) address page
boundaries, never a byte range that splits a page.

Every movement edge is CHUNK-STREAMED, not monolithic: the HOST_RAM ->
LOCAL_DISK spill and the DISK -> DEVICE promotion move per-chunk-sha256
npz entries (``checkpoint/io`` — a streamed restore overlaps disk
read/verify of entry *i+1* with the ``device_put`` of entry *i* and never
materializes the whole host snapshot), and the PEER edge ships a
:class:`~repro.core.streaming.ChunkPlan` of verified chunks::

      donor A  --chunks (lane 0, budgeted between decode steps)--+
      donor B  --chunks (lane 1)---------------------------------+--> cold
      SnapshotPool --params chunks (pool lane, HOST_RAM/DISK)----+   worker

A receiver stripes disjoint chunk ranges across several warm donors at
once — and this pool doubles as a stripe source for the immutable weight
chunks (``peek``: non-consuming read) — while each donor exports a few
chunks per mailbox turn so its own serving never stalls. A corrupt or
lost lane degrades alone (refs reassigned to a surviving lane, or the
receiver falls down the ladder); the fetch never restarts.

The PEER edge is the join-storm bootstrap path (paper §4.1): a cold
worker reaches DEVICE directly from a warm peer's exported template
(``repro.core.context.export_context`` — non-destructive, the donor keeps
serving) instead of through the shared filesystem. Which inbound edge a
cold worker takes is decided by COST, not fixed priority: the scheduler
scores every feasible FetchSource rung (PEER / POOL / DISK / FS / BUILD,
see ``repro.core.transfer``) in predicted seconds — the TransferPlanner's
EWMA-calibrated bandwidths, per-donor fanout shares, shared-FS contention
and the worker's own PCIe link — and takes the cheapest, so a
slow-measured donor loses to a local NVMe promotion. The canonical
PEER > POOL > DISK > FS > BUILD order is what uncalibrated defaults
produce for a paper-size context and remains the deterministic tie-break;
per-donor fanout admission still gates concurrent peer flows.

:class:`ContextStore` is the bookkeeping half (which keys are resident at
which tier, capacity-bounded with LRU eviction per tier); eviction from a
tier demotes nothing (re-fetch from below), matching worker sandbox
semantics. Admission REFUSES (raises :class:`TierFullError`) when pinned
entries block the eviction needed to make room — a tier never silently
exceeds its capacity.
"""

from __future__ import annotations

import threading
import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Set

import enum

from repro.core.context import GB, ContextRecipe, ContextSnapshot


class Tier(enum.IntEnum):
    SHARED_FS = 0      # always available (the cluster filesystem)
    LOCAL_DISK = 1
    HOST_RAM = 2
    DEVICE = 3


class ContextMode(enum.Enum):
    AGNOSTIC = "agnostic"
    PARTIAL = "partial"
    FULL = "full"

    @property
    def persist_tier(self) -> Tier:
        return {ContextMode.AGNOSTIC: Tier.SHARED_FS,
                ContextMode.PARTIAL: Tier.LOCAL_DISK,
                ContextMode.FULL: Tier.DEVICE}[self]


class TierFullError(ValueError):
    """Admission refused: the tier cannot make room because every eviction
    candidate is pinned (or the payload exceeds raw capacity)."""


@dataclass
class _Entry:
    key: str
    nbytes: int
    last_used: float = field(default_factory=time.monotonic)


class ContextStore:
    """Tracks which context keys are resident at which tier of one worker."""

    def __init__(self, disk_bytes: int = 70 * GB, host_bytes: int = 10 * GB,
                 device_bytes: int = 24 * GB):
        self.capacity = {Tier.LOCAL_DISK: disk_bytes,
                         Tier.HOST_RAM: host_bytes,
                         Tier.DEVICE: device_bytes}
        self._tiers: Dict[Tier, Dict[str, _Entry]] = {
            Tier.LOCAL_DISK: {}, Tier.HOST_RAM: {}, Tier.DEVICE: {}}
        self.evictions = 0
        self.pinned: Set[str] = set()

    # ------------------------------------------------------------- pinning --
    def pin(self, key: str):
        """Exempt ``key`` from LRU eviction and mode cleanup. Pinned entries
        never become eviction victims; once they fill a tier, further
        admissions are REFUSED with TierFullError rather than overcommitted."""
        self.pinned.add(key)

    def unpin(self, key: str):
        self.pinned.discard(key)

    def has(self, key: str, tier: Tier) -> bool:
        if tier == Tier.SHARED_FS:
            return True
        return key in self._tiers[tier]

    def highest_tier(self, key: str) -> Tier:
        for tier in (Tier.DEVICE, Tier.HOST_RAM, Tier.LOCAL_DISK):
            if key in self._tiers[tier]:
                return tier
        return Tier.SHARED_FS

    def used(self, tier: Tier) -> int:
        return sum(e.nbytes for e in self._tiers[tier].values())

    def pinned_bytes(self, tier: Tier) -> int:
        if tier == Tier.SHARED_FS:
            return 0
        return sum(e.nbytes for k, e in self._tiers[tier].items()
                   if k in self.pinned)

    def admit(self, key: str, tier: Tier, nbytes: int, now: float = None
              ) -> List[str]:
        """Place key at tier, LRU-evicting as needed. Returns evicted keys.

        Raises :class:`TierFullError` when the payload exceeds the tier's
        raw capacity, or when pinned entries block the evictions needed to
        make room — admission never silently overcommits a tier."""
        if tier == Tier.SHARED_FS:
            return []
        if nbytes > self.capacity[tier]:
            raise TierFullError(
                f"context {key} ({nbytes / GB:.1f} GB) exceeds tier "
                f"{tier.name} capacity ({self.capacity[tier] / GB:.1f} GB)")
        entries = self._tiers[tier]
        # re-admission replaces the existing entry: only the delta counts
        resident = entries[key].nbytes if key in entries else 0
        evicted = []
        while self.used(tier) - resident + nbytes > self.capacity[tier]:
            victim = min((e for k, e in entries.items()
                          if k != key and k not in self.pinned),
                         key=lambda e: e.last_used, default=None)
            if victim is None:
                raise TierFullError(
                    f"tier {tier.name} full admitting {key} "
                    f"({nbytes / GB:.1f} GB): {self.pinned_bytes(tier) / GB:.1f}"
                    f" GB pinned of {self.capacity[tier] / GB:.1f} GB "
                    "capacity and no evictable entries remain")
            del entries[victim.key]
            evicted.append(victim.key)
            self.evictions += 1
        now = time.monotonic() if now is None else now
        entries[key] = _Entry(key=key, nbytes=nbytes, last_used=now)
        return evicted

    def admit_recipe(self, recipe: ContextRecipe, upto: Tier,
                     now: float = None) -> List[str]:
        """Admit a recipe's footprint at every tier up to ``upto``.

        Atomic w.r.t. this key: if a higher tier refuses (TierFullError),
        residency this call just added at lower tiers is rolled back, so a
        failed admission never leaves phantom HOST_RAM/LOCAL_DISK entries
        for the scheduler's restore ladder to chase. (Evictions performed
        along the way are not undone — eviction is always lossy.)"""
        key = recipe.key()
        plan = [(Tier.LOCAL_DISK, recipe.transfer_bytes),
                (Tier.HOST_RAM, recipe.host_bytes),
                (Tier.DEVICE, recipe.device_bytes)]
        added = []
        evicted = []
        try:
            for tier, nbytes in plan:
                if upto >= tier:
                    was_resident = key in self._tiers[tier]
                    evicted += self.admit(key, tier, nbytes, now)
                    if not was_resident:
                        added.append(tier)
        except TierFullError:
            for tier in added:
                self._tiers[tier].pop(key, None)
            raise
        return evicted

    def touch(self, key: str, now: float = None):
        now = time.monotonic() if now is None else now
        for entries in self._tiers.values():
            if key in entries:
                entries[key].last_used = now

    def invalidate(self, key: str, tier: Tier):
        """Remove one key from ONE tier (no pin check): bookkeeping
        correction when the physical copy backing that tier is gone (e.g.
        the node pool's snapshot was consumed by another worker)."""
        if tier != Tier.SHARED_FS:
            self._tiers[tier].pop(key, None)

    def drop(self, key: str, down_to: Tier = Tier.SHARED_FS,
             force: bool = False):
        """Remove residency above ``down_to`` (mode cleanup after a task).
        Pinned keys survive unless ``force`` (worker actually gone)."""
        if key in self.pinned and not force:
            return
        for tier, entries in self._tiers.items():
            if tier > down_to:
                entries.pop(key, None)

    def clear(self, force: bool = False):
        for entries in self._tiers.values():
            if force or not self.pinned:
                entries.clear()
            else:
                for k in [k for k in entries if k not in self.pinned]:
                    del entries[k]

    def keys(self, tier: Tier) -> Set[str]:
        if tier == Tier.SHARED_FS:
            return set()
        return set(self._tiers[tier])

    def stats(self) -> Dict:
        """Per-tier occupancy incl. pinned bytes (admission headroom that
        eviction can never reclaim)."""
        return {
            "evictions": self.evictions,
            "tiers": {
                tier.name: {
                    "used_bytes": self.used(tier),
                    "capacity_bytes": self.capacity[tier],
                    "pinned_bytes": self.pinned_bytes(tier),
                    "entries": len(self._tiers[tier]),
                } for tier in (Tier.LOCAL_DISK, Tier.HOST_RAM, Tier.DEVICE)
            },
        }


class SnapshotPool:
    """Node-level pool of demoted :class:`ContextSnapshot` payloads.

    The physical half of tier movement: DEVICE->HOST_RAM demotion `put`s a
    snapshot here (params + engine device state pulled to host RAM via
    ``jax.device_get``, AOT-executable handles retained as metadata);
    when host occupancy exceeds ``host_bytes``, the LRU snapshot SPILLS its
    arrays to LOCAL_DISK through ``checkpoint/io`` (atomic npz + manifest).
    Promotion (`take`) returns the snapshot for restore and removes it from
    the pool — the materialized value is a single mutable object (engine +
    executables), so a restore MOVES it to the requesting worker rather
    than aliasing it across workers.

    The pool is owned by the node (PCMManager), not by one worker: it
    models host RAM + local disk surviving a no-warning GPU reclaim, which
    is exactly why a preempted-then-rejoining worker pays restore cost
    instead of full startup cost (the paper's core claim).

    Thread-safe: worker actor threads demote/restore concurrently.
    """

    def __init__(self, host_bytes: int = 48 * GB,
                 disk_bytes: int = 200 * GB,
                 spill_dir: Optional[str] = None,
                 on_gone=None,
                 chunk_bytes: int = 64 << 20):
        self.host_bytes = host_bytes
        self.disk_bytes = disk_bytes
        # chunk granularity of HOST_RAM -> LOCAL_DISK spills (per-chunk
        # sha256 manifests; streamed restores verify entry-by-entry)
        self.chunk_bytes = int(chunk_bytes)
        self._spill_dir = spill_dir
        self._spill_store = None            # lazy: repro.checkpoint.SpillStore
        # on_gone(key): fired (outside the pool lock) when a snapshot
        # leaves the pool without being re-insertable — consumed by a
        # restore or dropped for capacity — so owners of residency
        # bookkeeping can invalidate phantom HOST_RAM claims
        self._on_gone = on_gone
        self._snaps: Dict[str, ContextSnapshot] = {}
        self._lost_keys: List[str] = []     # dropped under lock, fired after
        self._lock = threading.RLock()
        self.demotions = 0
        self.spills = 0
        self.restores = 0
        self.restore_seconds = 0.0
        self.lost = 0                       # dropped for capacity, never used
        self.stripe_reads = 0               # chunks served as a stripe lane

    # ------------------------------------------------------------ internal --
    def spill_store(self):
        """The lazily created LOCAL_DISK backend (checkpoint SpillStore)."""
        if self._spill_store is None:
            from repro.checkpoint.manager import SpillStore
            self._spill_store = SpillStore(self._spill_dir)
        return self._spill_store

    def set_on_gone(self, cb):
        """Install the gone-notification callback (see ``__init__``) when
        the pool was constructed before its owner existed."""
        self._on_gone = cb

    def _host_used(self) -> int:
        return sum(s.nbytes for s in self._snaps.values()
                   if s.tier == Tier.HOST_RAM)

    def _disk_used(self) -> int:
        return sum(s.nbytes for s in self._snaps.values()
                   if s.tier == Tier.LOCAL_DISK)

    def _select_spill_victims(self) -> List[ContextSnapshot]:
        """LRU-pick HOST_RAM snapshots until host occupancy fits; caller
        holds the lock. Victims are REMOVED from the pool so the GB-scale
        npz write can happen outside the lock (a concurrent ``take`` of a
        mid-spill key simply misses and cold-builds); snapshots the disk
        tier cannot hold are dropped outright (rebuild is always
        possible)."""
        victims: List[ContextSnapshot] = []
        disk_planned = self._disk_used()
        while self._host_used() > self.host_bytes:
            cands = sorted((s for s in self._snaps.values()
                            if s.tier == Tier.HOST_RAM),
                           key=lambda s: s.last_used)
            if not cands:
                break
            victim = cands[0]
            del self._snaps[victim.key]
            if disk_planned + victim.nbytes <= self.disk_bytes:
                victims.append(victim)
                disk_planned += victim.nbytes
            else:
                self.lost += 1
                self._lost_keys.append(victim.key)
        return victims

    def _finish_spills(self, victims: List[ContextSnapshot]):
        """Re-insert spilled snapshots (disk writes done outside the
        lock); a snapshot superseded by a newer demotion of the same key
        while we were writing gets its disk copy discarded instead."""
        stale: List[ContextSnapshot] = []
        with self._lock:
            for v in victims:
                if v.key in self._snaps:
                    stale.append(v)
                else:
                    self._snaps[v.key] = v
                    self.spills += 1
        for v in stale:
            v.discard(self.spill_store())

    def _fire_gone(self):
        """Notify the owner about snapshots that left the pool for good
        (capacity drops); called WITHOUT the pool lock held."""
        if self._on_gone is None:
            with self._lock:
                self._lost_keys.clear()
            return
        with self._lock:
            keys, self._lost_keys = self._lost_keys, []
        for key in keys:
            self._on_gone(key)

    # -------------------------------------------------------------- public --
    def put(self, snap: ContextSnapshot):
        """Admit a freshly demoted snapshot at HOST_RAM (spilling LRU
        residents to disk as needed). Replaces any older snapshot of the
        same context. Disk I/O runs outside the pool lock so concurrent
        demotes/restores never serialize behind a multi-GB npz write."""
        with self._lock:
            old = self._snaps.pop(snap.key, None)
            self._snaps[snap.key] = snap
            self.demotions += 1
            victims = self._select_spill_victims()
        if old is not None and old.tier == Tier.LOCAL_DISK:
            old.discard(self.spill_store())
        for v in victims:
            v.spill(self.spill_store(), chunk_bytes=self.chunk_bytes)
        if victims:
            self._finish_spills(victims)
        self._fire_gone()

    def take(self, key: str) -> Optional[ContextSnapshot]:
        """Remove and return the snapshot for ``key`` (promotion consumes
        it — the value object moves to the restoring worker). Fires
        ``on_gone`` so residency bookkeeping recorded for this snapshot
        elsewhere (other workers' HOST_RAM claims) is invalidated."""
        with self._lock:
            snap = self._snaps.pop(key, None)
            if snap is not None:
                self.restores += 1
        if snap is not None and self._on_gone is not None:
            self._on_gone(key)
        return snap

    def peek(self, key: str) -> Optional[ContextSnapshot]:
        """Non-consuming read of the pooled snapshot — the handle a
        striped PEER fetch uses to serve immutable ``params`` chunks as an
        extra stripe lane (HOST_RAM arrays are never mutated in place, and
        a spilled snapshot's entries are read via the spill store, so a
        concurrent ``take`` at worst fails this lane — which then degrades
        to a donor lane instead of corrupting anything)."""
        with self._lock:
            return self._snaps.get(key)

    def spill(self, key: str) -> bool:
        """Explicitly demote one snapshot HOST_RAM -> LOCAL_DISK (the
        write happens outside the lock; the key is briefly absent from
        the pool while in flight)."""
        with self._lock:
            snap = self._snaps.pop(key, None)
            if snap is None or snap.tier != Tier.HOST_RAM:
                if snap is not None:      # disk-resident already: keep it
                    self._snaps[key] = snap
                return False
        snap.spill(self.spill_store(), chunk_bytes=self.chunk_bytes)
        self._finish_spills([snap])
        return True

    def tier(self, key: str) -> Optional[Tier]:
        with self._lock:
            snap = self._snaps.get(key)
            return None if snap is None else snap.tier

    def keys(self) -> Set[str]:
        with self._lock:
            return set(self._snaps)

    def discard(self, key: str):
        with self._lock:
            snap = self._snaps.pop(key, None)
        if snap is not None and snap.tier == Tier.LOCAL_DISK:
            snap.discard(self.spill_store())

    def stats(self) -> Dict:
        with self._lock:
            return {
                "snapshots": len(self._snaps),
                "host_used_bytes": self._host_used(),
                "disk_used_bytes": self._disk_used(),
                "demotions": self.demotions,
                "spills": self.spills,
                "restores": self.restores,
                "restore_seconds": self.restore_seconds,
                "lost": self.lost,
                "stripe_reads": self.stripe_reads,
            }
