"""Context bootstrap planner: shared filesystem vs peer-to-peer transfer.

The paper's insight (§1, §4.1): when many opportunistic workers arrive at
once, cold-starting them all from the shared filesystem saturates it (the
cluster's Panasas sustains ~84 Gb/s TOTAL); instead, workers that already
hold the context template serve it peer-to-peer, so aggregate bootstrap
bandwidth scales with the number of warm donors.

On the TPU adaptation, "P2P" is a device-to-device weight broadcast along
the ICI/DCN fabric (`jax.device_put` donor->slice / collective along the
pod axis) — same planning math, different wires.
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Set, Tuple

from repro.core.context import GB

GBPS = GB  # bytes/second per "gigabyte-per-second" unit


@dataclass
class TransferPlan:
    source: str                 # "shared_fs" or donor worker id
    seconds: float
    nbytes: int
    p2p: bool


@dataclass
class _Flow:
    done_at: float


class TransferPlanner:
    """Bandwidth-aware source selection with live flow tracking.

    shared-FS bandwidth is divided among concurrent FS pulls (the paper's
    filesystem bottleneck); each donor sustains ``p2p_bytes_per_s`` and
    serves ``donor_fanout`` concurrent receivers before saturating.
    """

    def __init__(self, fs_bytes_per_s: float = 84 / 8 * GBPS,
                 p2p_bytes_per_s: float = 10 * GBPS,
                 nic_bytes_per_s: float = 1.25 * GBPS,
                 donor_fanout: int = 2,
                 h2d_bytes_per_s: float = 16 * GBPS,
                 disk_bytes_per_s: float = 2 * GBPS):
        self.fs_bytes_per_s = fs_bytes_per_s      # aggregate Panasas
        self.p2p_bytes_per_s = p2p_bytes_per_s
        self.nic_bytes_per_s = nic_bytes_per_s    # per-node 10GbE cap
        self.donor_fanout = donor_fanout
        self.h2d_bytes_per_s = h2d_bytes_per_s    # host RAM -> HBM (PCIe)
        self.disk_bytes_per_s = disk_bytes_per_s  # local NVMe read
        self._fs_flows: List[_Flow] = []
        self._donor_flows: Dict[str, List[_Flow]] = {}

    # ------------------------------------------------------------ internal --
    def _gc(self, now: float):
        self._fs_flows = [f for f in self._fs_flows if f.done_at > now]
        for d in list(self._donor_flows):
            self._donor_flows[d] = [f for f in self._donor_flows[d]
                                    if f.done_at > now]
            if not self._donor_flows[d]:
                del self._donor_flows[d]

    def _fs_seconds(self, nbytes: int, now: float) -> float:
        concurrent = len(self._fs_flows) + 1
        rate = min(self.nic_bytes_per_s, self.fs_bytes_per_s / concurrent)
        return nbytes / rate

    def _donor_seconds(self, donor: str, nbytes: int) -> Optional[float]:
        flows = self._donor_flows.get(donor, [])
        if len(flows) >= self.donor_fanout:
            return None
        return nbytes / min(self.p2p_bytes_per_s, self.nic_bytes_per_s)

    # -------------------------------------------------------------- public --
    def plan(self, nbytes: int, donors: Set[str], now: float,
             allow_p2p: bool = True,
             fs_nbytes: Optional[int] = None) -> TransferPlan:
        """Pick the fastest currently-available source. ``fs_nbytes``
        overrides the FS payload (small-file metadata penalty on envs —
        P2P ships the packed template and is exempt)."""
        self._gc(now)
        best: Tuple[float, str, bool] = (
            self._fs_seconds(fs_nbytes if fs_nbytes is not None else nbytes,
                             now), "shared_fs", False)
        if allow_p2p:
            for d in sorted(donors):
                sec = self._donor_seconds(d, nbytes)
                if sec is not None and sec < best[0]:
                    best = (sec, d, True)
        seconds, source, p2p = best
        flow = _Flow(done_at=now + seconds)
        if p2p:
            self._donor_flows.setdefault(source, []).append(flow)
        else:
            self._fs_flows.append(flow)
        return TransferPlan(source=source, seconds=seconds, nbytes=nbytes,
                            p2p=p2p)

    def restore_seconds(self, nbytes: int, from_disk: bool = False,
                        h2d_bytes_per_s: Optional[float] = None) -> float:
        """Modeled promotion latency for a demoted context snapshot:
        host RAM -> HBM over PCIe, plus a local-disk read when the
        snapshot was spilled. This is the paper's restore cost — compare
        against ``plan(...)`` + build for the cold path. Pass the worker's
        own PCIe bandwidth via ``h2d_bytes_per_s`` when a device profile
        is known (the simulator does); the planner default is a generic
        Gen4 x16 link."""
        t = nbytes / (h2d_bytes_per_s or self.h2d_bytes_per_s)
        if from_disk:
            t += nbytes / self.disk_bytes_per_s
        return t

    def stats(self) -> Dict:
        return {"fs_active": len(self._fs_flows),
                "donors_active": {k: len(v)
                                  for k, v in self._donor_flows.items()}}
