"""Context bootstrap planning: the FetchSource ladder, bandwidth-aware
admission, and measured-transfer calibration.

The paper's insight (§1, §4.1): when many opportunistic workers arrive at
once, cold-starting them all from the shared filesystem saturates it (the
cluster's Panasas sustains ~84 Gb/s TOTAL); instead, workers that already
hold the context template serve it peer-to-peer, so aggregate bootstrap
bandwidth scales with the number of warm donors.

On the TPU adaptation, "P2P" is a device-to-device weight broadcast along
the ICI/DCN fabric (`jax.device_put` donor->slice / collective along the
pod axis) — same planning math, different wires.

The FetchSource ladder
----------------------
Every context acquisition — live or simulated — is one of five sources::

    PEER   donor->receiver snapshot transfer from a warm worker that holds
           the materialized context (template export; the donor keeps
           serving). Gated by per-donor fanout + bandwidth admission.
    POOL   promotion of a HOST_RAM snapshot from the node SnapshotPool
           (one host->HBM transfer; the snapshot is consumed).
    DISK   promotion of a LOCAL_DISK spill (npz read + host->HBM).
    FS     cold fetch of the artifact + env from the shared filesystem
           (modeled bandwidth in simulation; in-process the builder's own
           load path plays this role).
    BUILD  pure construction from scratch — no artifact to transfer.

Selection is COST-BASED, not fixed-priority: the scheduler scores every
feasible rung in predicted seconds — peer bandwidth at the donor's current
fanout share, pool/disk promotion over the receiving worker's own PCIe
link, the shared-FS share at the current contention level plus the cold
load, and a modeled build/compile cost — and picks the cheapest. The
EWMA-calibrated bandwidths from :meth:`TransferPlanner.complete` feed the
scores, so a donor that measured slow genuinely loses to a local NVMe
restore. The canonical order above (PEER > POOL > DISK > FS > BUILD) is
what the *uncalibrated* defaults produce for a paper-size context, and
remains the deterministic tie-break when two rungs predict equal seconds.

The :class:`~repro.core.scheduler.ContextAwareScheduler` owns the ladder
POLICY (``_choose_source``); this module owns the timing/admission MATH —
both the side-effect-free prediction surface (``peer_seconds``,
``cold_seconds``, ``build_seconds``, ``restore_seconds``) the chooser
scores with, and the flow-registering commit surface (``peer_plan``,
``fs_plan``, ``pool_plan``). Both execution backends (live PCMManager,
discrete-event simulator) speak the same vocabulary, which is what lets
one policy object drive both.

Live flows report their **measured** duration back through
:meth:`TransferPlanner.complete`, which (a) prunes the modeled flow the
moment the real transfer finishes — without this, long-lived modeled flows
make donors look saturated and the shared FS look contended for the whole
modeled duration, under-reporting the bandwidth actually available — and
(b) feeds an EWMA calibration of the per-path bandwidth so subsequent
plans use observed rates.
"""

from __future__ import annotations

import enum
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Set, Tuple

from repro.core.context import GB

GBPS = GB  # bytes/second per "gigabyte-per-second" unit


class FetchSource(enum.Enum):
    """Where a context acquisition comes from (see module docstring)."""

    PEER = "peer"
    POOL = "pool"
    DISK = "disk"
    FS = "fs"
    BUILD = "build"


@dataclass
class TransferPlan:
    source: str                 # "shared_fs", "pool", "disk" or donor id
    seconds: float
    nbytes: int
    p2p: bool
    fetch_source: FetchSource = FetchSource.FS
    # committed stripe lanes for a striped peer transfer (primary donor
    # first); single-donor plans carry a one-element tuple
    stripes: Tuple[str, ...] = ()
    # transport kind of a peer transfer: "memcpy" for in-process
    # thread-to-thread handoff, "socket" when any endpoint is a remote
    # process — calibration is namespaced per kind so wire lanes never
    # price from memcpy history (and vice versa)
    kind: str = "memcpy"

    def __post_init__(self):
        if self.p2p:
            self.fetch_source = FetchSource.PEER


@dataclass
class _Flow:
    done_at: float


class TransferPlanner:
    """Bandwidth-aware source selection with live flow tracking.

    shared-FS bandwidth is divided among concurrent FS pulls (the paper's
    filesystem bottleneck); each donor sustains ``p2p_bytes_per_s`` and
    serves ``donor_fanout`` concurrent receivers before saturating.

    Flow accounting: every planned transfer registers a flow whose modeled
    ``done_at`` gates later admission. Flows are pruned on EVERY read path
    (``plan``/``fs_load``/``donor_load``/``stats``) once ``done_at <= now``,
    and a live runtime should call :meth:`complete` the moment a transfer
    actually finishes — measured completions both free the donor/FS slot
    early and calibrate the planner's bandwidth estimates (EWMA over
    observed bytes/second).
    """

    def __init__(self, fs_bytes_per_s: float = 84 / 8 * GBPS,
                 p2p_bytes_per_s: float = 10 * GBPS,
                 nic_bytes_per_s: float = 1.25 * GBPS,
                 donor_fanout: int = 2,
                 h2d_bytes_per_s: float = 16 * GBPS,
                 disk_bytes_per_s: float = 2 * GBPS,
                 warmup_seconds: float = 16.0,
                 builder_bytes_per_s: float = 0.05 * GBPS,
                 d2h_bytes_per_s: float = 12 * GBPS,
                 chunk_bytes: int = 64 << 20):
        self.fs_bytes_per_s = fs_bytes_per_s      # aggregate Panasas
        self.p2p_bytes_per_s = p2p_bytes_per_s
        self.nic_bytes_per_s = nic_bytes_per_s    # per-node 10GbE cap
        self.donor_fanout = donor_fanout
        self.h2d_bytes_per_s = h2d_bytes_per_s    # host RAM -> HBM (PCIe)
        self.disk_bytes_per_s = disk_bytes_per_s  # local NVMe read
        # cold-path cost knobs for the scheduler's rung scoring: framework
        # warm-up on any from-scratch load (mirrors CostModel.
        # framework_warmup_s), and the modeled from-scratch construction
        # throughput — weight init + AOT compiles amortized over the
        # artifact payload, calibrated so a paper-size context builds in
        # minutes (the paper's 'minutes-long startup')
        self.warmup_seconds = warmup_seconds
        self.builder_bytes_per_s = builder_bytes_per_s
        self.d2h_bytes_per_s = d2h_bytes_per_s    # HBM -> host (donor export)
        # chunk granularity of streamed movement: the pipeline fill latency
        # (one chunk traversing every stage before steady-state overlap)
        self.chunk_bytes = chunk_bytes
        self._fs_flows: List[_Flow] = []
        self._donor_flows: Dict[str, List[_Flow]] = {}
        # measured-bandwidth calibration (EWMA bytes/s per path), fed by
        # complete(); None until the first live observation. Peer paths
        # are namespaced PER TRANSPORT KIND: an in-process memcpy handoff
        # measures orders of magnitude above a 10GbE socket lane, so a
        # shared "p2p" bucket would misprice the first wire transfer by
        # the same factor. A cold socket lane prices from the
        # conservative NIC default until its own observations arrive.
        self._measured: Dict[str, Optional[float]] = {
            "p2p:memcpy": None, "p2p:socket": None, "fs": None}
        # per-stage calibration for the pipelined rung scores, fed by
        # observe_stage() from live streamed movement
        self._measured_stage: Dict[str, Optional[float]] = {
            "d2h": None, "h2d": None, "disk": None}
        self._calibration_alpha = 0.5
        self.completed_flows = 0
        self.failed_flows = 0

    # ------------------------------------------------------------ internal --
    def _gc(self, now: float):
        """Prune flows whose modeled completion has passed. Called from
        every read path: a stale flow (done_at <= now) must never count
        against bandwidth shares or donor fanout."""
        self._fs_flows = [f for f in self._fs_flows if f.done_at > now]
        for d in list(self._donor_flows):
            self._donor_flows[d] = [f for f in self._donor_flows[d]
                                    if f.done_at > now]
            if not self._donor_flows[d]:
                del self._donor_flows[d]

    def _p2p_rate(self, kind: str = "memcpy") -> float:
        measured = self._measured.get(f"p2p:{kind}")
        if measured is not None:
            return measured
        if kind == "socket":
            return self.nic_bytes_per_s
        return min(self.p2p_bytes_per_s, self.nic_bytes_per_s)

    def _fs_rate(self, concurrent: int) -> float:
        measured = self._measured["fs"]
        if measured is not None:
            return measured / max(1, concurrent)
        return min(self.nic_bytes_per_s, self.fs_bytes_per_s / concurrent)

    def _fs_seconds(self, nbytes: int, now: float) -> float:
        concurrent = len(self._fs_flows) + 1
        return nbytes / self._fs_rate(concurrent)

    def _donor_seconds(self, donor: str, nbytes: int,
                       kind: str = "memcpy") -> Optional[float]:
        """Predicted seconds of one more transfer from ``donor``: the
        donor's uplink splits across its in-flight flows plus this one,
        then the per-flow rate is NIC-capped — a lightly loaded donor's
        receivers each still get their full NIC. A measured (EWMA) rate is
        already a per-flow rate observed under real contention, so it is
        used as-is rather than re-divided. Rates are looked up in the
        transport kind's own namespace — socket lanes never price from
        memcpy history. None when fanout-saturated."""
        flows = self._donor_flows.get(donor, [])
        if len(flows) >= self.donor_fanout:
            return None
        measured = self._measured.get(f"p2p:{kind}")
        if measured is not None:
            return nbytes / measured
        uplink = self.nic_bytes_per_s if kind == "socket" \
            else self.p2p_bytes_per_s
        share = uplink / (len(flows) + 1)
        return nbytes / min(share, self.nic_bytes_per_s)

    def _ranked_free_donors(self, donors: Set[str]) -> List[str]:
        """Free-slot donors, least-loaded first (best fanout share), id
        tie-break for determinism. Callers must have _gc'd already."""
        return sorted(
            (d for d in donors
             if len(self._donor_flows.get(d, [])) < self.donor_fanout),
            key=lambda d: (len(self._donor_flows.get(d, [])), d))

    def _stage_rate(self, stage: str,
                    override: Optional[float] = None) -> float:
        """Bytes/s for one pipeline stage: an explicit per-worker override
        wins (the scheduler passes each worker's own PCIe rate), else the
        live EWMA observation, else the modeled default."""
        if override is not None:
            return override
        measured = self._measured_stage.get(stage)
        if measured is not None:
            return measured
        return {"d2h": self.d2h_bytes_per_s,
                "h2d": self.h2d_bytes_per_s,
                "disk": self.disk_bytes_per_s}[stage]

    def _stripe_lanes(self, nbytes: int, donors: Set[str], width: int,
                      kinds: Optional[Dict[str, str]] = None
                      ) -> Optional[Tuple[List[str], float]]:
        """Up to ``width`` free donor lanes (least-loaded first) splitting
        ``nbytes`` into disjoint chunk ranges; seconds is the slowest
        lane's wire time. ``kinds`` maps donor id -> transport kind for
        this receiver (default memcpy). Callers must have _gc'd already."""
        ranked = self._ranked_free_donors(donors)
        if not ranked:
            return None
        lanes = ranked[:max(1, width)]
        per = -(-nbytes // len(lanes))
        sec = max(self._donor_seconds(d, per,
                                      kind=(kinds or {}).get(d, "memcpy"))
                  for d in lanes)
        return lanes, sec

    # -------------------------------------------------------------- public --
    def fs_load(self, now: float) -> int:
        """Concurrent shared-FS pulls still in flight at ``now``."""
        self._gc(now)
        return len(self._fs_flows)

    def donor_load(self, donor: str, now: float) -> int:
        """Concurrent receivers this donor is serving at ``now``."""
        self._gc(now)
        return len(self._donor_flows.get(donor, []))

    def plan(self, nbytes: int, donors: Set[str], now: float,
             allow_p2p: bool = True,
             fs_nbytes: Optional[int] = None) -> TransferPlan:
        """Pick the fastest currently-available source. ``fs_nbytes``
        overrides the FS payload (small-file metadata penalty on envs —
        P2P ships the packed template and is exempt)."""
        self._gc(now)
        best: Tuple[float, str, bool] = (
            self._fs_seconds(fs_nbytes if fs_nbytes is not None else nbytes,
                             now), "shared_fs", False)
        if allow_p2p:
            for d in sorted(donors):
                sec = self._donor_seconds(d, nbytes)
                if sec is not None and sec < best[0]:
                    best = (sec, d, True)
        seconds, source, p2p = best
        return self._register(TransferPlan(source=source, seconds=seconds,
                                           nbytes=nbytes, p2p=p2p), now)

    def peer_seconds(self, nbytes: int, donors: Set[str], now: float,
                     width: int = 1,
                     kinds: Optional[Dict[str, str]] = None
                     ) -> Optional[Tuple[str, float]]:
        """Side-effect-free prediction of the best admissible peer
        transfer: ``(primary_donor, seconds)``, or None when every donor
        is saturated. With ``width > 1`` the payload stripes across up to
        that many free donors (disjoint chunk ranges, slowest lane
        bounds), which is how multi-source striping shows up in the cost
        score. This is the PEER rung's score in the scheduler's cost
        chooser AND the selection the commit call (:meth:`peer_plan`)
        reuses — one code path, so the dry and commit decisions cannot
        drift."""
        self._gc(now)
        got = self._stripe_lanes(nbytes, donors, width, kinds=kinds)
        if got is None:
            return None
        lanes, sec = got
        return lanes[0], sec

    def peer_rate_seconds(self, nbytes: int, kind: str = "memcpy") -> float:
        """Predicted seconds of an UNCONSTRAINED peer transfer at the
        calibrated point-to-point rate (no fanout share): what a transfer
        would cost once a donor slot frees — the donor-wait cost bound."""
        return nbytes / self._p2p_rate(kind)

    def pipeline_seconds(self, stages: List[float], nbytes: int) -> float:
        """Latency of ``nbytes`` moving through serial ``stages`` (each a
        whole-payload seconds figure) CHUNK-PIPELINED: once the first
        chunk has traversed every stage, all stages run concurrently and
        the bottleneck stage sets the rate. ``fill = chunk/nbytes`` blends
        between the degenerate cases exactly — one chunk (fill=1) costs
        the old sum-of-stages, many chunks cost the bottleneck stage plus
        one chunk's worth of the others."""
        stages = [s for s in stages if s > 0]
        if not stages:
            return 0.0
        fill = min(1.0, self.chunk_bytes / max(1, nbytes))
        return fill * sum(stages) + (1.0 - fill) * max(stages)

    def d2h_seconds(self, nbytes: int) -> float:
        """Donor-side export stage: HBM -> host at the (calibrated)
        device_get rate."""
        return nbytes / self._stage_rate("d2h")

    def observe_stage(self, stage: str, nbytes: int, seconds: float):
        """Fold a live per-stage measurement (d2h/h2d/disk) into the
        pipeline calibration EWMA."""
        if stage not in self._measured_stage or seconds <= 0 or nbytes <= 0:
            return
        rate = nbytes / seconds
        prev = self._measured_stage[stage]
        a = self._calibration_alpha
        self._measured_stage[stage] = rate if prev is None \
            else a * rate + (1 - a) * prev

    def cold_load_seconds(self, transfer_bytes: int, host_bytes: int,
                          h2d_bytes_per_s: Optional[float] = None) -> float:
        """The load a fresh process pays once its artifact is node-local:
        framework warm-up, then local-disk read pipelined against the
        host->HBM promotion (chunked entries stream to device as they are
        read). Both the tail of the FS rung score (:meth:`cold_seconds`)
        and the post-transfer half of a committed FS fetch's ETA."""
        return self.warmup_seconds + self.pipeline_seconds(
            [transfer_bytes / self._stage_rate("disk"),
             host_bytes / self._stage_rate("h2d", h2d_bytes_per_s)],
            transfer_bytes)

    def cold_seconds(self, transfer_bytes: int, host_bytes: int, now: float,
                     h2d_bytes_per_s: Optional[float] = None) -> float:
        """Side-effect-free prediction of the FS rung end-to-end: framework
        warm-up plus the shared-FS fetch (at the CURRENT contention level)
        pipelined against the local-disk pass and the host->HBM
        promotion."""
        self._gc(now)
        return self.warmup_seconds + self.pipeline_seconds(
            [self._fs_seconds(transfer_bytes, now),
             transfer_bytes / self._stage_rate("disk"),
             host_bytes / self._stage_rate("h2d", h2d_bytes_per_s)],
            transfer_bytes)

    def build_seconds(self, transfer_bytes: int) -> float:
        """Modeled cost of the BUILD rung: framework warm-up plus from-
        scratch construction of the context payload (weight init + AOT
        compiles) at ``builder_bytes_per_s``. Deliberately slow per byte —
        building a paper-size context takes minutes, so BUILD only wins
        the cost race when there is (almost) nothing to transfer."""
        return self.warmup_seconds + transfer_bytes / self.builder_bytes_per_s

    def peer_plan(self, nbytes: int, donors: Set[str], now: float,
                  width: int = 1,
                  kinds: Optional[Dict[str, str]] = None
                  ) -> Optional[TransferPlan]:
        """Commit a P2P transfer from the best available donors (the same
        :meth:`peer_seconds` selection), or None when every donor is
        saturated (the scheduler then either waits for a slot or takes
        the cheapest remaining rung). With ``width > 1`` the commit
        stripes across up to that many free donors: one fanout flow per
        lane, ``plan.stripes`` naming the lanes (primary first). The
        plan's transport ``kind`` is socket when ANY lane crosses a
        process boundary, so measured completion calibrates the wire
        namespace, not memcpy."""
        self._gc(now)
        got = self._stripe_lanes(nbytes, donors, width, kinds=kinds)
        if got is None:
            return None
        lanes, sec = got
        kind = "socket" if any((kinds or {}).get(d) == "socket"
                               for d in lanes) else "memcpy"
        plan = TransferPlan(source=lanes[0], seconds=sec, nbytes=nbytes,
                            p2p=True, stripes=tuple(lanes), kind=kind)
        flows = []
        for d in lanes:
            flow = _Flow(done_at=now + sec)
            self._donor_flows.setdefault(d, []).append(flow)
            flows.append(flow)
        plan._flows = flows
        plan._flow = flows[0]
        return plan

    def fs_plan(self, nbytes: int, now: float,
                fs_nbytes: Optional[int] = None) -> TransferPlan:
        """Plan a shared-FS fetch at the current contention level."""
        self._gc(now)
        eff = fs_nbytes if fs_nbytes is not None else nbytes
        return self._register(
            TransferPlan(source="shared_fs",
                         seconds=self._fs_seconds(eff, now),
                         nbytes=nbytes, p2p=False), now)

    def pool_plan(self, nbytes: int, now: float,
                  from_disk: bool = False,
                  h2d_bytes_per_s: Optional[float] = None) -> TransferPlan:
        """Plan a snapshot promotion from the node pool (POOL/DISK rungs).
        Node-local PCIe/NVMe bandwidth — no shared-fabric flow to track."""
        plan = TransferPlan(
            source="disk" if from_disk else "pool",
            seconds=self.restore_seconds(nbytes, from_disk=from_disk,
                                         h2d_bytes_per_s=h2d_bytes_per_s),
            nbytes=nbytes, p2p=False,
            fetch_source=FetchSource.DISK if from_disk else FetchSource.POOL)
        return plan

    def _register(self, plan: TransferPlan, now: float) -> TransferPlan:
        flow = _Flow(done_at=now + plan.seconds)
        plan._flow = flow
        if plan.p2p:
            self._donor_flows.setdefault(plan.source, []).append(flow)
        else:
            self._fs_flows.append(flow)
        return plan

    def complete(self, plan: TransferPlan, now: float,
                 measured_seconds: Optional[float] = None,
                 failed: bool = False):
        """Report a planned transfer finished at ``now`` (live runtimes
        call this from the receiving worker). Frees the flow(s)
        immediately — the stale-flow fix: without it a fast real transfer
        would keep its donor/FS slot occupied for the whole MODELED
        duration — and, given ``measured_seconds``, folds the observed
        bytes/second into the planner's EWMA calibration. A ``failed``
        completion (dead donor/receiver, corrupt payload, degraded fetch)
        still frees every lane's flow — a dead transfer must not linger
        as a phantom in-flight flow inflating fanout shares — but counts
        under ``failed_flows`` and never touches the EWMA."""
        flows = getattr(plan, "_flows", None)
        if flows is None:
            flow = getattr(plan, "_flow", None)
            flows = [] if flow is None else [flow]
        for flow in flows:
            # pool_plan promotions are node-local and never registered a
            # flow: nothing to free, and they must not count as transfers
            flow.done_at = min(flow.done_at, now)
        if flows:
            self._gc(now)
            if failed:
                self.failed_flows += 1
            else:
                self.completed_flows += 1
        if failed:
            return
        if measured_seconds is not None and measured_seconds > 0 \
                and plan.fetch_source in (FetchSource.PEER, FetchSource.FS):
            path = f"p2p:{getattr(plan, 'kind', 'memcpy')}" \
                if plan.p2p else "fs"
            rate = plan.nbytes / measured_seconds
            prev = self._measured.get(path)
            a = self._calibration_alpha
            self._measured[path] = rate if prev is None \
                else a * rate + (1 - a) * prev

    def restore_seconds(self, nbytes: int, from_disk: bool = False,
                        h2d_bytes_per_s: Optional[float] = None) -> float:
        """Modeled promotion latency for a demoted context snapshot:
        host RAM -> HBM over PCIe, pipelined against the local-disk read
        when the snapshot was spilled (streamed restores ``device_put``
        entry *i* while entry *i+1* is read and verified). This is the
        paper's restore cost — compare against ``plan(...)`` + build for
        the cold path. Pass the worker's own PCIe bandwidth via
        ``h2d_bytes_per_s`` when a device profile is known (the simulator
        does); the planner default is a generic Gen4 x16 link."""
        stages = [nbytes / self._stage_rate("h2d", h2d_bytes_per_s)]
        if from_disk:
            stages.append(nbytes / self._stage_rate("disk"))
        return self.pipeline_seconds(stages, nbytes)

    def calibration(self) -> Dict:
        """Observed bytes/s per path (None until live feedback arrives).
        ``p2p`` remains an alias for the in-process memcpy namespace;
        socket-lane observations live under ``p2p:socket``."""
        out = dict(self._measured)
        out["p2p"] = self._measured["p2p:memcpy"]
        out.update(self._measured_stage)
        return out

    def stats(self, now: Optional[float] = None) -> Dict:
        if now is not None:
            self._gc(now)
        return {"fs_active": len(self._fs_flows),
                "donors_active": {k: len(v)
                                  for k, v in self._donor_flows.items()},
                "completed_flows": self.completed_flows,
                "failed_flows": self.failed_flows,
                "measured_bytes_per_s": self.calibration()}
