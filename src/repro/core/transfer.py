"""Context bootstrap planning: the FetchSource ladder, bandwidth-aware
admission, and measured-transfer calibration.

The paper's insight (§1, §4.1): when many opportunistic workers arrive at
once, cold-starting them all from the shared filesystem saturates it (the
cluster's Panasas sustains ~84 Gb/s TOTAL); instead, workers that already
hold the context template serve it peer-to-peer, so aggregate bootstrap
bandwidth scales with the number of warm donors.

On the TPU adaptation, "P2P" is a device-to-device weight broadcast along
the ICI/DCN fabric (`jax.device_put` donor->slice / collective along the
pod axis) — same planning math, different wires.

The FetchSource ladder
----------------------
Every context acquisition — live or simulated — is one of five sources::

    PEER   donor->receiver snapshot transfer from a warm worker that holds
           the materialized context (template export; the donor keeps
           serving). Gated by per-donor fanout + bandwidth admission.
    POOL   promotion of a HOST_RAM snapshot from the node SnapshotPool
           (one host->HBM transfer; the snapshot is consumed).
    DISK   promotion of a LOCAL_DISK spill (npz read + host->HBM).
    FS     cold fetch of the artifact + env from the shared filesystem
           (modeled bandwidth in simulation; in-process the builder's own
           load path plays this role).
    BUILD  pure construction from scratch — no artifact to transfer.

Selection is COST-BASED, not fixed-priority: the scheduler scores every
feasible rung in predicted seconds — peer bandwidth at the donor's current
fanout share, pool/disk promotion over the receiving worker's own PCIe
link, the shared-FS share at the current contention level plus the cold
load, and a modeled build/compile cost — and picks the cheapest. The
EWMA-calibrated bandwidths from :meth:`TransferPlanner.complete` feed the
scores, so a donor that measured slow genuinely loses to a local NVMe
restore. The canonical order above (PEER > POOL > DISK > FS > BUILD) is
what the *uncalibrated* defaults produce for a paper-size context, and
remains the deterministic tie-break when two rungs predict equal seconds.

The :class:`~repro.core.scheduler.ContextAwareScheduler` owns the ladder
POLICY (``_choose_source``); this module owns the timing/admission MATH —
both the side-effect-free prediction surface (``peer_seconds``,
``cold_seconds``, ``build_seconds``, ``restore_seconds``) the chooser
scores with, and the flow-registering commit surface (``peer_plan``,
``fs_plan``, ``pool_plan``). Both execution backends (live PCMManager,
discrete-event simulator) speak the same vocabulary, which is what lets
one policy object drive both.

Live flows report their **measured** duration back through
:meth:`TransferPlanner.complete`, which (a) prunes the modeled flow the
moment the real transfer finishes — without this, long-lived modeled flows
make donors look saturated and the shared FS look contended for the whole
modeled duration, under-reporting the bandwidth actually available — and
(b) feeds an EWMA calibration of the per-path bandwidth so subsequent
plans use observed rates.
"""

from __future__ import annotations

import enum
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Set, Tuple

from repro.core.context import GB

GBPS = GB  # bytes/second per "gigabyte-per-second" unit


class FetchSource(enum.Enum):
    """Where a context acquisition comes from (see module docstring)."""

    PEER = "peer"
    POOL = "pool"
    DISK = "disk"
    FS = "fs"
    BUILD = "build"


@dataclass
class TransferPlan:
    source: str                 # "shared_fs", "pool", "disk" or donor id
    seconds: float
    nbytes: int
    p2p: bool
    fetch_source: FetchSource = FetchSource.FS

    def __post_init__(self):
        if self.p2p:
            self.fetch_source = FetchSource.PEER


@dataclass
class _Flow:
    done_at: float


class TransferPlanner:
    """Bandwidth-aware source selection with live flow tracking.

    shared-FS bandwidth is divided among concurrent FS pulls (the paper's
    filesystem bottleneck); each donor sustains ``p2p_bytes_per_s`` and
    serves ``donor_fanout`` concurrent receivers before saturating.

    Flow accounting: every planned transfer registers a flow whose modeled
    ``done_at`` gates later admission. Flows are pruned on EVERY read path
    (``plan``/``fs_load``/``donor_load``/``stats``) once ``done_at <= now``,
    and a live runtime should call :meth:`complete` the moment a transfer
    actually finishes — measured completions both free the donor/FS slot
    early and calibrate the planner's bandwidth estimates (EWMA over
    observed bytes/second).
    """

    def __init__(self, fs_bytes_per_s: float = 84 / 8 * GBPS,
                 p2p_bytes_per_s: float = 10 * GBPS,
                 nic_bytes_per_s: float = 1.25 * GBPS,
                 donor_fanout: int = 2,
                 h2d_bytes_per_s: float = 16 * GBPS,
                 disk_bytes_per_s: float = 2 * GBPS,
                 warmup_seconds: float = 16.0,
                 builder_bytes_per_s: float = 0.05 * GBPS):
        self.fs_bytes_per_s = fs_bytes_per_s      # aggregate Panasas
        self.p2p_bytes_per_s = p2p_bytes_per_s
        self.nic_bytes_per_s = nic_bytes_per_s    # per-node 10GbE cap
        self.donor_fanout = donor_fanout
        self.h2d_bytes_per_s = h2d_bytes_per_s    # host RAM -> HBM (PCIe)
        self.disk_bytes_per_s = disk_bytes_per_s  # local NVMe read
        # cold-path cost knobs for the scheduler's rung scoring: framework
        # warm-up on any from-scratch load (mirrors CostModel.
        # framework_warmup_s), and the modeled from-scratch construction
        # throughput — weight init + AOT compiles amortized over the
        # artifact payload, calibrated so a paper-size context builds in
        # minutes (the paper's 'minutes-long startup')
        self.warmup_seconds = warmup_seconds
        self.builder_bytes_per_s = builder_bytes_per_s
        self._fs_flows: List[_Flow] = []
        self._donor_flows: Dict[str, List[_Flow]] = {}
        # measured-bandwidth calibration (EWMA bytes/s per path), fed by
        # complete(); None until the first live observation
        self._measured: Dict[str, Optional[float]] = {"p2p": None, "fs": None}
        self._calibration_alpha = 0.5
        self.completed_flows = 0

    # ------------------------------------------------------------ internal --
    def _gc(self, now: float):
        """Prune flows whose modeled completion has passed. Called from
        every read path: a stale flow (done_at <= now) must never count
        against bandwidth shares or donor fanout."""
        self._fs_flows = [f for f in self._fs_flows if f.done_at > now]
        for d in list(self._donor_flows):
            self._donor_flows[d] = [f for f in self._donor_flows[d]
                                    if f.done_at > now]
            if not self._donor_flows[d]:
                del self._donor_flows[d]

    def _p2p_rate(self) -> float:
        measured = self._measured["p2p"]
        if measured is not None:
            return measured
        return min(self.p2p_bytes_per_s, self.nic_bytes_per_s)

    def _fs_rate(self, concurrent: int) -> float:
        measured = self._measured["fs"]
        if measured is not None:
            return measured / max(1, concurrent)
        return min(self.nic_bytes_per_s, self.fs_bytes_per_s / concurrent)

    def _fs_seconds(self, nbytes: int, now: float) -> float:
        concurrent = len(self._fs_flows) + 1
        return nbytes / self._fs_rate(concurrent)

    def _donor_seconds(self, donor: str, nbytes: int) -> Optional[float]:
        """Predicted seconds of one more transfer from ``donor``: the
        donor's uplink splits across its in-flight flows plus this one,
        then the per-flow rate is NIC-capped — a lightly loaded donor's
        receivers each still get their full NIC. A measured (EWMA) rate is
        already a per-flow rate observed under real contention, so it is
        used as-is rather than re-divided. None when fanout-saturated."""
        flows = self._donor_flows.get(donor, [])
        if len(flows) >= self.donor_fanout:
            return None
        measured = self._measured["p2p"]
        if measured is not None:
            return nbytes / measured
        share = self.p2p_bytes_per_s / (len(flows) + 1)
        return nbytes / min(share, self.nic_bytes_per_s)

    def _ranked_free_donors(self, donors: Set[str]) -> List[str]:
        """Free-slot donors, least-loaded first (best fanout share), id
        tie-break for determinism. Callers must have _gc'd already."""
        return sorted(
            (d for d in donors
             if len(self._donor_flows.get(d, [])) < self.donor_fanout),
            key=lambda d: (len(self._donor_flows.get(d, [])), d))

    # -------------------------------------------------------------- public --
    def fs_load(self, now: float) -> int:
        """Concurrent shared-FS pulls still in flight at ``now``."""
        self._gc(now)
        return len(self._fs_flows)

    def donor_load(self, donor: str, now: float) -> int:
        """Concurrent receivers this donor is serving at ``now``."""
        self._gc(now)
        return len(self._donor_flows.get(donor, []))

    def plan(self, nbytes: int, donors: Set[str], now: float,
             allow_p2p: bool = True,
             fs_nbytes: Optional[int] = None) -> TransferPlan:
        """Pick the fastest currently-available source. ``fs_nbytes``
        overrides the FS payload (small-file metadata penalty on envs —
        P2P ships the packed template and is exempt)."""
        self._gc(now)
        best: Tuple[float, str, bool] = (
            self._fs_seconds(fs_nbytes if fs_nbytes is not None else nbytes,
                             now), "shared_fs", False)
        if allow_p2p:
            for d in sorted(donors):
                sec = self._donor_seconds(d, nbytes)
                if sec is not None and sec < best[0]:
                    best = (sec, d, True)
        seconds, source, p2p = best
        return self._register(TransferPlan(source=source, seconds=seconds,
                                           nbytes=nbytes, p2p=p2p), now)

    def peer_seconds(self, nbytes: int, donors: Set[str], now: float
                     ) -> Optional[Tuple[str, float]]:
        """Side-effect-free prediction of the best admissible peer
        transfer: ``(donor, seconds)`` from the least-loaded free donor at
        its current fanout share, or None when every donor is saturated.
        This is the PEER rung's score in the scheduler's cost chooser AND
        the selection the commit call (:meth:`peer_plan`) reuses — one
        code path, so the dry and commit decisions cannot drift."""
        self._gc(now)
        ranked = self._ranked_free_donors(donors)
        if not ranked:
            return None
        return ranked[0], self._donor_seconds(ranked[0], nbytes)

    def peer_rate_seconds(self, nbytes: int) -> float:
        """Predicted seconds of an UNCONSTRAINED peer transfer at the
        calibrated point-to-point rate (no fanout share): what a transfer
        would cost once a donor slot frees — the donor-wait cost bound."""
        return nbytes / self._p2p_rate()

    def cold_load_seconds(self, transfer_bytes: int, host_bytes: int,
                          h2d_bytes_per_s: Optional[float] = None) -> float:
        """The load a fresh process pays once its artifact is node-local:
        framework warm-up + local-disk read + host->HBM promotion. Both
        the tail of the FS rung score (:meth:`cold_seconds`) and the
        post-transfer half of a committed FS fetch's ETA."""
        return (self.warmup_seconds
                + transfer_bytes / self.disk_bytes_per_s
                + host_bytes / (h2d_bytes_per_s or self.h2d_bytes_per_s))

    def cold_seconds(self, transfer_bytes: int, host_bytes: int, now: float,
                     h2d_bytes_per_s: Optional[float] = None) -> float:
        """Side-effect-free prediction of the FS rung end-to-end: shared-FS
        fetch at the CURRENT contention level, then the cold load a fresh
        process pays (:meth:`cold_load_seconds`)."""
        self._gc(now)
        return (self._fs_seconds(transfer_bytes, now)
                + self.cold_load_seconds(transfer_bytes, host_bytes,
                                         h2d_bytes_per_s))

    def build_seconds(self, transfer_bytes: int) -> float:
        """Modeled cost of the BUILD rung: framework warm-up plus from-
        scratch construction of the context payload (weight init + AOT
        compiles) at ``builder_bytes_per_s``. Deliberately slow per byte —
        building a paper-size context takes minutes, so BUILD only wins
        the cost race when there is (almost) nothing to transfer."""
        return self.warmup_seconds + transfer_bytes / self.builder_bytes_per_s

    def peer_plan(self, nbytes: int, donors: Set[str], now: float
                  ) -> Optional[TransferPlan]:
        """Commit a P2P transfer from the best available donor (the same
        :meth:`peer_seconds` selection), or None when every donor is
        saturated (the scheduler then either waits for a slot or takes
        the cheapest remaining rung)."""
        best = self.peer_seconds(nbytes, donors, now)
        if best is None:
            return None
        donor, sec = best
        return self._register(TransferPlan(source=donor, seconds=sec,
                                           nbytes=nbytes, p2p=True), now)

    def fs_plan(self, nbytes: int, now: float,
                fs_nbytes: Optional[int] = None) -> TransferPlan:
        """Plan a shared-FS fetch at the current contention level."""
        self._gc(now)
        eff = fs_nbytes if fs_nbytes is not None else nbytes
        return self._register(
            TransferPlan(source="shared_fs",
                         seconds=self._fs_seconds(eff, now),
                         nbytes=nbytes, p2p=False), now)

    def pool_plan(self, nbytes: int, now: float,
                  from_disk: bool = False,
                  h2d_bytes_per_s: Optional[float] = None) -> TransferPlan:
        """Plan a snapshot promotion from the node pool (POOL/DISK rungs).
        Node-local PCIe/NVMe bandwidth — no shared-fabric flow to track."""
        plan = TransferPlan(
            source="disk" if from_disk else "pool",
            seconds=self.restore_seconds(nbytes, from_disk=from_disk,
                                         h2d_bytes_per_s=h2d_bytes_per_s),
            nbytes=nbytes, p2p=False,
            fetch_source=FetchSource.DISK if from_disk else FetchSource.POOL)
        return plan

    def _register(self, plan: TransferPlan, now: float) -> TransferPlan:
        flow = _Flow(done_at=now + plan.seconds)
        plan._flow = flow
        if plan.p2p:
            self._donor_flows.setdefault(plan.source, []).append(flow)
        else:
            self._fs_flows.append(flow)
        return plan

    def complete(self, plan: TransferPlan, now: float,
                 measured_seconds: Optional[float] = None):
        """Report a planned transfer finished at ``now`` (live runtimes
        call this from the receiving worker). Frees the flow immediately —
        the stale-flow fix: without it a fast real transfer would keep its
        donor/FS slot occupied for the whole MODELED duration — and, given
        ``measured_seconds``, folds the observed bytes/second into the
        planner's EWMA calibration."""
        flow = getattr(plan, "_flow", None)
        if flow is not None:
            # pool_plan promotions are node-local and never registered a
            # flow: nothing to free, and they must not count as transfers
            flow.done_at = min(flow.done_at, now)
            self._gc(now)
            self.completed_flows += 1
        if measured_seconds is not None and measured_seconds > 0 \
                and plan.fetch_source in (FetchSource.PEER, FetchSource.FS):
            path = "p2p" if plan.p2p else "fs"
            rate = plan.nbytes / measured_seconds
            prev = self._measured[path]
            a = self._calibration_alpha
            self._measured[path] = rate if prev is None \
                else a * rate + (1 - a) * prev

    def restore_seconds(self, nbytes: int, from_disk: bool = False,
                        h2d_bytes_per_s: Optional[float] = None) -> float:
        """Modeled promotion latency for a demoted context snapshot:
        host RAM -> HBM over PCIe, plus a local-disk read when the
        snapshot was spilled. This is the paper's restore cost — compare
        against ``plan(...)`` + build for the cold path. Pass the worker's
        own PCIe bandwidth via ``h2d_bytes_per_s`` when a device profile
        is known (the simulator does); the planner default is a generic
        Gen4 x16 link."""
        t = nbytes / (h2d_bytes_per_s or self.h2d_bytes_per_s)
        if from_disk:
            t += nbytes / self.disk_bytes_per_s
        return t

    def calibration(self) -> Dict:
        """Observed bytes/s per path (None until live feedback arrives)."""
        return dict(self._measured)

    def stats(self, now: Optional[float] = None) -> Dict:
        if now is not None:
            self._gc(now)
        return {"fs_active": len(self._fs_flows),
                "donors_active": {k: len(v)
                                  for k, v in self._donor_flows.items()},
                "completed_flows": self.completed_flows,
                "measured_bytes_per_s": dict(self._measured)}
